//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so the simulator carries its own
//! small, well-known generators: SplitMix64 for seeding / one-shot mixing
//! and PCG32 (XSH-RR 64/32) for streams.  Every simulator component takes
//! an explicit seed so whole experiments replay bit-identically — the
//! integration tests assert this.

/// SplitMix64: fast 64-bit mixer, used for seed derivation.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): the simulator's workhorse stream generator.
///
/// `stream` selects one of 2^63 distinct sequences, letting each core /
/// warp / component own an independent stream derived from one root seed.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a child generator; used to fan a root seed out to components.
    pub fn split(&mut self, salt: u64) -> Pcg32 {
        let mut mix = SplitMix64::new(self.next_u64() ^ salt);
        Pcg32::new(mix.next_u64(), mix.next_u64())
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire reduction).
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo < hi);
        lo + self.next_below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Geometric-ish gap: number of failures before a success with prob `p`,
    /// capped to keep pathological draws bounded.
    pub fn geometric(&mut self, p: f64, cap: u32) -> u32 {
        if p >= 1.0 {
            return 0;
        }
        let p = p.max(1e-9);
        let u = self.next_f64().max(1e-300);
        let g = (u.ln() / (1.0 - p).ln()).floor();
        (g as u32).min(cap)
    }
}

/// A Zipf sampler over `n` items (power-law reuse, used by workload models
/// for hot-line distributions).  Rejection-inversion sampling (Hörmann &
/// Derflinger) over the continuous Zipf density.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u32,
    exponent: f64,
    h_integral_x1: f64,
    h_integral_n: f64,
    s: f64,
}

impl Zipf {
    pub fn new(n: u32, exponent: f64) -> Self {
        assert!(n > 0);
        let h_integral_x1 = Self::h_integral(1.5, exponent) - 1.0;
        let h_integral_n = Self::h_integral(n as f64 + 0.5, exponent);
        let s = 2.0
            - Self::h_integral_inv(
                Self::h_integral(2.5, exponent) - Self::h(2.0, exponent),
                exponent,
            );
        Self {
            n,
            exponent,
            h_integral_x1,
            h_integral_n,
            s,
        }
    }

    fn h_integral(x: f64, e: f64) -> f64 {
        let log_x = x.ln();
        helper2((1.0 - e) * log_x) * log_x
    }

    fn h(x: f64, e: f64) -> f64 {
        (-e * x.ln()).exp()
    }

    fn h_integral_inv(x: f64, e: f64) -> f64 {
        let mut t = x * (1.0 - e);
        if t < -1.0 {
            t = -1.0;
        }
        (helper1(t) * x).exp()
    }

    /// Draw a 0-based rank (0 is the hottest item).
    pub fn sample(&self, rng: &mut Pcg32) -> u32 {
        loop {
            let u = self.h_integral_n
                + rng.next_f64() * (self.h_integral_x1 - self.h_integral_n);
            let x = Self::h_integral_inv(u, self.exponent);
            let k64 = x.clamp(1.0, self.n as f64);
            let k = ((k64 + 0.5) as u32).clamp(1, self.n);
            if (k as f64 - x).abs() <= self.s
                || u >= Self::h_integral(k as f64 + 0.5, self.exponent)
                    - Self::h(k as f64, self.exponent)
            {
                return k - 1;
            }
        }
    }
}

/// `log1p(x) / x`, stable near 0.
fn helper1(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.ln_1p() / x
    } else {
        1.0 - x * (0.5 - x * (1.0 / 3.0 - 0.25 * x))
    }
}

/// `expm1(x) / x`, stable near 0.
fn helper2(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.exp_m1() / x
    } else {
        1.0 + x * 0.5 * (1.0 + x * (1.0 / 3.0) * (1.0 + 0.25 * x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_sequence_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::new(1, 0);
        let mut b = Pcg32::new(1, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be nearly disjoint, {same} collisions");
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(7, 3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.next_below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut rng = Pcg32::new(9, 1);
        for _ in 0..1000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn chance_rate_roughly_matches() {
        let mut rng = Pcg32::new(11, 5);
        let hits = (0..10_000).filter(|_| rng.chance(0.3)).count();
        assert!((2700..3300).contains(&hits), "got {hits}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(13, 1);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Pcg32::new(17, 2);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(1000, 1.0);
        let mut rng = Pcg32::new(23, 4);
        let mut head = 0usize;
        for _ in 0..10_000 {
            let k = z.sample(&mut rng);
            assert!(k < 1000);
            if k < 10 {
                head += 1;
            }
        }
        // Zipf(1.0, n=1000): top-10 mass ≈ H(10)/H(1000) ≈ 0.39
        assert!(head > 2500, "zipf head mass too small: {head}");
    }

    #[test]
    fn zipf_exponent_zero_is_uniformish() {
        let z = Zipf::new(100, 0.01);
        let mut rng = Pcg32::new(37, 8);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(min > 0 && max < min * 4, "min={min} max={max}");
    }

    #[test]
    fn geometric_mean_tracks_p() {
        let mut rng = Pcg32::new(29, 6);
        let n = 20_000;
        let total: u64 = (0..n).map(|_| rng.geometric(0.25, 1000) as u64).sum();
        let mean = total as f64 / n as f64;
        // E[failures before success] = (1-p)/p = 3.0
        assert!((2.8..3.2).contains(&mean), "mean {mean}");
    }

    #[test]
    fn split_children_independent() {
        let mut root = Pcg32::new(31, 7);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
