//! Tiny CLI argument parser (the offline crate set has no `clap`).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [--key=value] [pos..]`.
//! Typed getters parse on demand and collect errors with helpful messages.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

/// Argument-parsing failure (reported to the user on stderr).
#[derive(Debug)]
pub enum CliError {
    /// `--key` appeared in value position with nothing following it.
    MissingValue(String),
    /// `--key value` where the value does not parse as the expected type.
    BadValue(String, String, &'static str),
    /// An option no getter recognises.
    Unknown(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(k) => write!(f, "option --{k} expects a value"),
            CliError::BadValue(k, v, ty) => {
                write!(f, "option --{k}: cannot parse '{v}' as {ty}")
            }
            CliError::Unknown(k) => write!(f, "unknown option --{k}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` when the next token isn't an option,
                    // otherwise a bare flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.options.insert(rest.to_string(), v);
                        }
                        _ => out.flags.push(rest.to_string()),
                    }
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into(), "usize")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into(), "u64")),
        }
    }

    /// Shared parser for the host-parallelism knobs (`--threads`,
    /// `--shards`): one code path so the two can never diverge in
    /// parsing or error handling, only in their defaults.
    fn pool_size(&self, name: &str, default: usize) -> Result<usize, CliError> {
        self.get_usize(name, default)
    }

    /// The `--threads N` option every sweep surface shares, defaulting
    /// to the execution layer's notion of available parallelism (the
    /// runner clamps zero to one worker).
    pub fn get_threads(&self) -> Result<usize, CliError> {
        self.pool_size("threads", crate::exec::JobRunner::available())
    }

    /// The `--shards N` option (intra-job cluster sharding).  Defaults
    /// to 1 — the sequential engine loop — because sharding is opt-in
    /// until its barrier cost has been measured against real workloads;
    /// the engine clamps over-sharding to the cluster count and `0` is
    /// rejected by `GpuConfig::validate`.
    pub fn get_shards(&self) -> Result<usize, CliError> {
        self.pool_size("shards", 1)
    }

    /// The `--mem-workers N` option (phase-B2 slice-walk workers).
    /// Defaults to 1 — the serial walk — mirroring `--shards`; the walk
    /// pool clamps over-provisioning to the L2 slice count and `0` is
    /// rejected by `GpuConfig::validate`.
    pub fn get_mem_workers(&self) -> Result<usize, CliError> {
        self.pool_size("mem-workers", 1)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into(), "f64")),
        }
    }

    /// Comma-separated list option, e.g. `--apps b+tree,cfd`.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["run", "b+tree", "cfd"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["b+tree", "cfd"]);
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = parse(&["run", "--arch", "ata", "--cores=30"]);
        assert_eq!(a.get("arch"), Some("ata"));
        assert_eq!(a.get_usize("cores", 0).unwrap(), 30);
    }

    #[test]
    fn bare_flags() {
        let a = parse(&["run", "--verbose", "--arch", "ata", "--json"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("json"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("arch"), Some("ata"));
    }

    #[test]
    fn flag_followed_by_option_not_swallowed() {
        let a = parse(&["--dry-run", "--out=x.json"]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = parse(&["x", "--f", "1.5"]);
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_f64("g", 2.5).unwrap(), 2.5);
        assert_eq!(a.get_u64("seed", 42).unwrap(), 42);
    }

    #[test]
    fn bad_value_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn threads_option_defaults_to_host_parallelism() {
        let a = parse(&["sweep", "--threads", "3"]);
        assert_eq!(a.get_threads().unwrap(), 3);
        let b = parse(&["sweep"]);
        assert!(b.get_threads().unwrap() >= 1);
        let c = parse(&["sweep", "--threads", "zero"]);
        assert!(c.get_threads().is_err());
    }

    #[test]
    fn shards_option_defaults_to_sequential() {
        let a = parse(&["run", "--shards", "4"]);
        assert_eq!(a.get_shards().unwrap(), 4);
        let b = parse(&["run"]);
        assert_eq!(b.get_shards().unwrap(), 1, "sharding is opt-in");
        assert!(b.get("shards").is_none(), "absence is distinguishable");
        let c = parse(&["run", "--shards", "two"]);
        assert!(c.get_shards().is_err(), "same error path as --threads");
    }

    #[test]
    fn mem_workers_option_defaults_to_serial() {
        let a = parse(&["run", "--mem-workers", "4"]);
        assert_eq!(a.get_mem_workers().unwrap(), 4);
        let b = parse(&["run"]);
        assert_eq!(b.get_mem_workers().unwrap(), 1, "parallel walk is opt-in");
        assert!(b.get("mem-workers").is_none(), "absence is distinguishable");
        let c = parse(&["run", "--mem-workers", "two"]);
        assert!(c.get_mem_workers().is_err(), "same error path as --threads");
    }

    #[test]
    fn list_option() {
        let a = parse(&["x", "--apps", "b+tree, cfd ,SN"]);
        assert_eq!(a.get_list("apps"), vec!["b+tree", "cfd", "SN"]);
        assert!(a.get_list("none").is_empty());
    }
}
