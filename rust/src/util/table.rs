//! Text rendering for benchmark output: aligned tables and ASCII bar
//! charts, so every `cargo bench` target prints the same rows/series the
//! paper's tables and figures report.

use std::fmt::Write as _;

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(|s| s.as_str()).unwrap_or("");
                let pad = w - cell.chars().count();
                // Right-align numeric-looking cells, left-align text.
                let numeric = cell
                    .chars()
                    .all(|c| c.is_ascii_digit() || ".-+%x×".contains(c))
                    && !cell.is_empty();
                if numeric {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                } else {
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                }
                if i + 1 < widths.len() {
                    line.push_str("  ");
                }
            }
            line
        };
        if !self.header.is_empty() {
            let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
            let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
            let _ = writeln!(out, "{}", "-".repeat(total));
        }
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }
}

/// Horizontal ASCII bar chart — one bar per labeled value, like a figure
/// series.  `baseline` draws a reference column (e.g. private cache = 1.0).
#[derive(Debug, Clone)]
pub struct BarChart {
    title: String,
    bars: Vec<(String, f64)>,
    baseline: Option<f64>,
    width: usize,
}

impl BarChart {
    pub fn new(title: &str) -> Self {
        BarChart {
            title: title.to_string(),
            bars: Vec::new(),
            baseline: None,
            width: 50,
        }
    }

    pub fn baseline(mut self, v: f64) -> Self {
        self.baseline = Some(v);
        self
    }

    pub fn bar(&mut self, label: &str, value: f64) -> &mut Self {
        self.bars.push((label.to_string(), value));
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "-- {} --", self.title);
        }
        let max = self
            .bars
            .iter()
            .map(|(_, v)| *v)
            .fold(self.baseline.unwrap_or(0.0), f64::max)
            .max(1e-12);
        let lwidth = self.bars.iter().map(|(l, _)| l.chars().count()).max().unwrap_or(0);
        for (label, value) in &self.bars {
            let n = ((value / max) * self.width as f64).round().max(0.0) as usize;
            let mut bar: String = "█".repeat(n.min(self.width));
            if let Some(b) = self.baseline {
                let bpos = ((b / max) * self.width as f64).round() as usize;
                // Mark the baseline with '|' if it's beyond the bar tip.
                if bpos > n && bpos <= self.width {
                    bar.push_str(&" ".repeat(bpos - n - 1));
                    bar.push('|');
                }
            }
            let _ = writeln!(
                out,
                "{label:<lw$}  {value:>8.3}  {bar}",
                lw = lwidth,
            );
        }
        out
    }
}

/// Format a ratio as a percentage delta, e.g. 1.12 -> "+12.0%".
pub fn pct_delta(ratio: f64) -> String {
    format!("{:+.1}%", (ratio - 1.0) * 100.0)
}

/// Geometric mean (the paper's "on average" for normalized IPC).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new("demo").header(&["app", "ipc", "norm"]);
        t.row(vec!["b+tree".into(), "1.25".into(), "1.12".into()]);
        t.row(vec!["cfd".into(), "0.5".into(), "1.08".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("b+tree"));
        // numeric right-alignment: "1.25" and " 0.5" line up on the right
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn table_handles_ragged_rows() {
        let mut t = Table::new("").header(&["a", "b"]);
        t.row(vec!["x".into()]);
        t.row(vec!["y".into(), "z".into(), "extra".into()]);
        let s = t.render();
        assert!(s.contains("extra"));
    }

    #[test]
    fn barchart_renders_scaled_bars() {
        let mut c = BarChart::new("ipc").baseline(1.0);
        c.bar("private", 1.0);
        c.bar("ata", 1.12);
        let s = c.render();
        assert!(s.contains("ata"));
        let private_len = s.lines().find(|l| l.starts_with("private")).unwrap().matches('█').count();
        let ata_len = s.lines().find(|l| l.starts_with("ata")).unwrap().matches('█').count();
        assert!(ata_len > private_len);
    }

    #[test]
    fn pct_delta_formats() {
        assert_eq!(pct_delta(1.12), "+12.0%");
        assert_eq!(pct_delta(0.9), "-10.0%");
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }
}
