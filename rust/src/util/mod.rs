//! Self-contained substrates: RNG, JSON, CLI parsing, text tables.
//!
//! The build environment resolves crates offline from a fixed vendor set
//! (no `rand`/`serde`/`clap`), so these are first-class modules with their
//! own tests rather than dependencies.

pub mod cli;
pub mod fxhash;
pub mod json;
pub mod rng;
pub mod table;
