//! Warp programs: the instruction streams the workload models generate.
//!
//! A `WarpProgram` is the coalesced, warp-level view of a GPU kernel as
//! the memory system sees it: runs of ALU issue slots separated by loads
//! (each already coalesced into per-cache-line requests) and stores.

use crate::mem::{LineAddr, SectorMask};

/// One warp-level instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum WarpInst {
    /// `n` back-to-back ALU instructions (each occupies one issue slot).
    Alu(u16),
    /// A load, coalesced into one request per distinct cache line.
    Load(Vec<(LineAddr, SectorMask)>),
    /// A store (fire-and-forget).
    Store(Vec<(LineAddr, SectorMask)>),
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct WarpProgram {
    insts: Vec<WarpInst>,
}

impl WarpProgram {
    pub fn new(insts: Vec<WarpInst>) -> Self {
        debug_assert!(
            insts.iter().all(|i| match i {
                WarpInst::Load(v) => !v.is_empty(),
                WarpInst::Alu(_) | WarpInst::Store(_) => true,
            }),
            "loads must carry at least one request"
        );
        WarpProgram { insts }
    }

    pub fn insts(&self) -> &[WarpInst] {
        &self.insts
    }

    /// Total issue slots this program occupies (ALU blocks expand).
    pub fn issue_slots(&self) -> u64 {
        self.insts
            .iter()
            .map(|i| match i {
                WarpInst::Alu(n) => (*n).max(1) as u64,
                _ => 1,
            })
            .sum()
    }

    /// Number of memory requests the program will issue.
    pub fn request_count(&self) -> u64 {
        self.insts
            .iter()
            .map(|i| match i {
                WarpInst::Load(v) | WarpInst::Store(v) => v.len() as u64,
                _ => 0,
            })
            .sum()
    }

    /// Shift every referenced line address by `delta` — used to give each
    /// co-executed application a disjoint address space (line addresses
    /// are virtual, so a plain offset models per-process isolation).
    pub fn offset_lines(&mut self, delta: LineAddr) {
        for inst in &mut self.insts {
            if let WarpInst::Load(reqs) | WarpInst::Store(reqs) = inst {
                for (line, _) in reqs.iter_mut() {
                    *line = line.wrapping_add(delta);
                }
            }
        }
    }

    /// Distinct lines the program touches (footprint).
    pub fn touched_lines(&self) -> Vec<LineAddr> {
        let mut lines: Vec<LineAddr> = self
            .insts
            .iter()
            .flat_map(|i| match i {
                WarpInst::Load(v) | WarpInst::Store(v) => v.iter().map(|&(l, _)| l).collect(),
                _ => Vec::new(),
            })
            .collect();
        lines.sort_unstable();
        lines.dedup();
        lines
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_slots_expand_alu_blocks() {
        let p = WarpProgram::new(vec![
            WarpInst::Alu(5),
            WarpInst::Load(vec![(1, 1)]),
            WarpInst::Alu(3),
        ]);
        assert_eq!(p.issue_slots(), 9);
        assert_eq!(p.request_count(), 1);
    }

    #[test]
    fn touched_lines_dedup() {
        let p = WarpProgram::new(vec![
            WarpInst::Load(vec![(3, 1), (1, 1)]),
            WarpInst::Store(vec![(3, 1)]),
        ]);
        assert_eq!(p.touched_lines(), vec![1, 3]);
        assert_eq!(p.request_count(), 3);
    }
}
