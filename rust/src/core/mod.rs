//! SIMT core model: warps, greedy-then-oldest (GTO) schedulers, and
//! memory-request issue.
//!
//! The model is warp-granular and memory-focused (like the paper's
//! evaluation): ALU work appears as issue-slot occupancy between memory
//! instructions, loads block the warp until every coalesced request
//! completes, stores are fire-and-forget.  Each core has
//! `schedulers_per_core` GTO schedulers that each issue one warp
//! instruction per cycle (Table II: 4 GTO schedulers/core).

pub mod program;

pub use program::{WarpInst, WarpProgram};

use crate::config::GpuConfig;
use crate::mem::{AccessKind, MemRequest, ReqId};

/// A contiguous block of SIMT cores assigned to one co-executing
/// application (the unit of spatial multitasking in
/// [`crate::engine::MultiWorkload`]).
///
/// Partitions are expressed in *global* core ids; `local`/`global`
/// translate between an application's core-local view (how its
/// [`KernelSpec`](crate::engine::KernelSpec) programs are indexed) and
/// the engine's global view (how requests are routed through the shared
/// L1 organization).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CorePartition {
    /// First global core id owned by this partition.
    pub first: usize,
    /// Number of cores in the partition.
    pub count: usize,
}

impl CorePartition {
    /// One past the last global core id.
    pub fn end(&self) -> usize {
        self.first + self.count
    }

    /// Does this partition own global core `core`?
    pub fn contains(&self, core: usize) -> bool {
        (self.first..self.end()).contains(&core)
    }

    /// Global core id of partition-local core `local`.
    pub fn global(&self, local: usize) -> usize {
        debug_assert!(local < self.count);
        self.first + local
    }

    /// Partition-local index of global core `core`.
    pub fn local(&self, core: usize) -> usize {
        debug_assert!(self.contains(core));
        core - self.first
    }

    /// Split `total` cores into consecutive disjoint partitions of the
    /// given sizes.  Fails when a size is zero or the sizes oversubscribe
    /// `total`; under-subscription is allowed (the tail cores stay idle).
    pub fn split(total: usize, sizes: &[usize]) -> Result<Vec<CorePartition>, String> {
        let mut first = 0;
        let mut out = Vec::with_capacity(sizes.len());
        for (i, &count) in sizes.iter().enumerate() {
            if count == 0 {
                return Err(format!("partition {i} has zero cores"));
            }
            if first + count > total {
                return Err(format!(
                    "partitions need {} cores but the GPU has {total}",
                    sizes.iter().sum::<usize>()
                ));
            }
            out.push(CorePartition { first, count });
            first += count;
        }
        Ok(out)
    }

    /// Split `total` cores evenly into `n` partitions (remainder cores go
    /// to the leading partitions, one each).
    pub fn even(total: usize, n: usize) -> Result<Vec<CorePartition>, String> {
        if n == 0 || n > total {
            return Err(format!("cannot split {total} cores into {n} partitions"));
        }
        let base = total / n;
        let extra = total % n;
        let sizes: Vec<usize> = (0..n).map(|i| base + usize::from(i < extra)).collect();
        CorePartition::split(total, &sizes)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WarpState {
    /// Can issue at or after the contained cycle.
    Ready(u64),
    /// Blocked on outstanding load requests.
    WaitingMem,
    Done,
}

#[derive(Debug)]
struct Warp {
    program: WarpProgram,
    pc: usize,
    /// Remaining ALU issue slots of the current Alu block.
    alu_left: u16,
    state: WarpState,
    /// Load-instruction sequence counter (latency-metric grouping key).
    inst_seq: u64,
}

impl Warp {
    fn done(&self) -> bool {
        self.state == WarpState::Done
    }

    fn ready_at(&self) -> Option<u64> {
        match self.state {
            WarpState::Ready(c) => Some(c),
            _ => None,
        }
    }
}

/// One GTO scheduler: sticks with the current warp while it can issue,
/// otherwise switches to the *oldest* ready warp (warp id = age; kernels
/// launch all warps at t=0).
#[derive(Debug)]
struct Scheduler {
    warp_ids: Vec<usize>,
    current: Option<usize>,
}

/// The result of one core cycle.
#[derive(Debug, Default)]
pub struct IssueBatch {
    /// Coalesced memory requests issued this cycle, each tagged with the
    /// number of requests its load instruction produced (for the latency
    /// tracker) — stores carry 0.
    pub requests: Vec<(MemRequest, u32)>,
    pub insts_issued: u64,
}

#[derive(Debug)]
pub struct SimtCore {
    pub id: u32,
    warps: Vec<Warp>,
    schedulers: Vec<Scheduler>,
    pub insts: u64,
    /// Scheduler-slots that found nothing to issue, one per scheduler
    /// per stalled cycle.  Clock-cadence-independent: cycles the
    /// event-driven engine skips are batch-charged on the next `tick`
    /// (see there), so both clock modes agree exactly.  Host telemetry
    /// only — never part of result JSON.
    pub stall_cycles: u64,
    /// Cycle of the previous `tick` (`u64::MAX` before the first); the
    /// anchor for the batch stall charge across clock jumps.
    last_tick: u64,
    next_req_id: ReqId,
    /// Earliest cycle this core could issue again (perf fast path: lets
    /// `tick` and the engine skip blocked cores in O(1); u64::MAX = never,
    /// 0 = unknown/now).
    next_event_hint: u64,
}

impl SimtCore {
    /// Create a core running `programs` (one per warp).  Programs beyond
    /// `max_warps_per_core` are rejected by the engine's launcher.
    pub fn new(id: u32, cfg: &GpuConfig, programs: Vec<WarpProgram>) -> Self {
        assert!(programs.len() <= cfg.max_warps_per_core);
        let n_sched = cfg.schedulers_per_core;
        let mut schedulers: Vec<Scheduler> = (0..n_sched)
            .map(|_| Scheduler {
                warp_ids: Vec::new(),
                current: None,
            })
            .collect();
        let warps: Vec<Warp> = programs
            .into_iter()
            .enumerate()
            .map(|(w, p)| {
                schedulers[w % n_sched].warp_ids.push(w);
                Warp {
                    state: if p.insts().is_empty() {
                        WarpState::Done
                    } else {
                        WarpState::Ready(0)
                    },
                    program: p,
                    pc: 0,
                    alu_left: 0,
                    inst_seq: 0,
                }
            })
            .collect();
        SimtCore {
            id,
            warps,
            schedulers,
            insts: 0,
            stall_cycles: 0,
            last_tick: u64::MAX,
            next_req_id: (id as u64) << 40,
            next_event_hint: 0,
        }
    }

    /// Earliest cycle the core might issue (valid after a `tick`).
    pub fn next_event_hint(&self) -> u64 {
        self.next_event_hint
    }

    pub fn all_done(&self) -> bool {
        self.warps.iter().all(Warp::done)
    }

    /// Earliest cycle any warp can issue (for idle fast-forward); None if
    /// every warp is done or waiting on memory.
    pub fn next_ready_cycle(&self) -> Option<u64> {
        self.warps.iter().filter_map(Warp::ready_at).min()
    }

    /// Wake a warp whose last outstanding load completed at `cycle`.
    pub fn wake_warp(&mut self, warp: u32, cycle: u64) {
        self.next_event_hint = self.next_event_hint.min(cycle);
        let w = &mut self.warps[warp as usize];
        debug_assert_eq!(w.state, WarpState::WaitingMem);
        w.state = WarpState::Ready(cycle);
    }

    /// Run one cycle: each scheduler issues at most one warp instruction,
    /// and the core as a whole issues at most one *memory* instruction
    /// (the shared LDST port, as in GPGPU-Sim's SM model).
    pub fn tick(&mut self, cycle: u64, out: &mut IssueBatch) {
        // Batch-charge stalls for cycles the event clock skipped: the
        // engine only jumps over cycles in which every core's hint
        // exceeds the clock (the horizon is the min over all hints and
        // no wake lands inside the jump), which are exactly the cycles
        // where the reference clock's fast path below charges one stall
        // per scheduler — so `stall_cycles` agrees in both clock modes.
        if self.last_tick != u64::MAX {
            debug_assert!(cycle > self.last_tick, "tick must advance the clock");
            self.stall_cycles += (cycle - self.last_tick - 1) * self.schedulers.len() as u64;
        }
        self.last_tick = cycle;
        // Fast path: nothing can issue before the cached hint.
        if self.next_event_hint > cycle {
            self.stall_cycles += self.schedulers.len() as u64;
            return;
        }
        let insts_before = out.insts_issued;
        let mut ldst_free = true;
        for s in 0..self.schedulers.len() {
            // GTO pick: keep current if it can issue, else oldest ready.
            // A warp whose next instruction needs the LDST port cannot
            // issue once the port is taken this cycle.
            let pick = {
                let sched = &self.schedulers[s];
                let can_issue = |w: usize| {
                    let warp = &self.warps[w];
                    let ready = matches!(warp.state, WarpState::Ready(c) if c <= cycle);
                    if !ready {
                        return false;
                    }
                    let is_mem = warp.alu_left == 0
                        && matches!(
                            warp.program.insts()[warp.pc],
                            WarpInst::Load(_) | WarpInst::Store(_)
                        );
                    !is_mem || ldst_free
                };
                match sched.current {
                    Some(w) if can_issue(w) => Some(w),
                    _ => sched.warp_ids.iter().copied().find(|&w| can_issue(w)),
                }
            };
            let Some(wid) = pick else {
                self.stall_cycles += 1;
                self.schedulers[s].current = None;
                continue;
            };
            self.schedulers[s].current = Some(wid);
            let used_mem = self.issue_from_warp(wid, cycle, out);
            if used_mem {
                ldst_free = false;
            }
        }
        self.next_event_hint = if out.insts_issued > insts_before {
            cycle + 1
        } else {
            self.next_ready_cycle().unwrap_or(u64::MAX)
        };
    }

    /// Returns true if the instruction used the LDST port.
    fn issue_from_warp(&mut self, wid: usize, cycle: u64, out: &mut IssueBatch) -> bool {
        let core = self.id;
        let w = &mut self.warps[wid];
        debug_assert!(matches!(w.state, WarpState::Ready(c) if c <= cycle));

        // Mid-ALU-block: burn one issue slot.
        if w.alu_left > 0 {
            w.alu_left -= 1;
            self.insts += 1;
            out.insts_issued += 1;
            if w.alu_left == 0 {
                w.pc += 1;
                if w.pc >= w.program.insts().len() {
                    w.state = WarpState::Done;
                }
            }
            return false;
        }

        match &w.program.insts()[w.pc] {
            WarpInst::Alu(n) => {
                let n = (*n).max(1);
                w.alu_left = n - 1;
                self.insts += 1;
                out.insts_issued += 1;
                if w.alu_left == 0 {
                    w.pc += 1;
                    if w.pc >= w.program.insts().len() {
                        w.state = WarpState::Done;
                    }
                }
                false
            }
            WarpInst::Load(lines) => {
                debug_assert!(!lines.is_empty());
                let inst = w.inst_seq;
                w.inst_seq += 1;
                let n = lines.len() as u32;
                for &(line, sectors) in lines.iter() {
                    let id = self.next_req_id;
                    self.next_req_id += 1;
                    out.requests.push((
                        MemRequest {
                            id,
                            core,
                            warp: wid as u32,
                            inst,
                            line,
                            sectors,
                            kind: AccessKind::Load,
                            issue_cycle: cycle,
                        },
                        n,
                    ));
                }
                self.insts += 1;
                out.insts_issued += 1;
                w.state = WarpState::WaitingMem;
                w.pc += 1;
                // `Done` is deferred until the wake if this was the last
                // instruction; a warp waiting on memory is not done.
                true
            }
            WarpInst::Store(lines) => {
                let inst = w.inst_seq;
                w.inst_seq += 1;
                for &(line, sectors) in lines.iter() {
                    let id = self.next_req_id;
                    self.next_req_id += 1;
                    out.requests.push((
                        MemRequest {
                            id,
                            core,
                            warp: wid as u32,
                            inst,
                            line,
                            sectors,
                            kind: AccessKind::Store,
                            issue_cycle: cycle,
                        },
                        0,
                    ));
                }
                self.insts += 1;
                out.insts_issued += 1;
                w.pc += 1;
                if w.pc >= w.program.insts().len() {
                    w.state = WarpState::Done;
                } else {
                    w.state = WarpState::Ready(cycle + 1);
                }
                true
            }
        }
    }

    /// Called by the engine when the last outstanding request of a blocked
    /// warp's load completes: wake or retire the warp.
    pub fn load_complete(&mut self, warp: u32, cycle: u64) {
        self.next_event_hint = self.next_event_hint.min(cycle + 1);
        let done = {
            let w = &self.warps[warp as usize];
            w.pc >= w.program.insts().len()
        };
        let w = &mut self.warps[warp as usize];
        if done {
            w.state = WarpState::Done;
        } else {
            w.state = WarpState::Ready(cycle + 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::L1ArchKind;

    fn cfg() -> GpuConfig {
        GpuConfig::tiny(L1ArchKind::Private)
    }

    fn run_alu_only(programs: Vec<WarpProgram>, cfg: &GpuConfig) -> (u64, u64) {
        let mut core = SimtCore::new(0, cfg, programs);
        let mut cycles = 0;
        while !core.all_done() {
            let mut out = IssueBatch::default();
            core.tick(cycles, &mut out);
            assert!(out.requests.is_empty());
            cycles += 1;
            assert!(cycles < 100_000);
        }
        (core.insts, cycles)
    }

    #[test]
    fn single_warp_alu_ipc_is_one_per_scheduler_slot() {
        let p = WarpProgram::new(vec![WarpInst::Alu(100)]);
        let (insts, cycles) = run_alu_only(vec![p], &cfg());
        assert_eq!(insts, 100);
        assert_eq!(cycles, 100, "1 inst/cycle from one warp");
    }

    #[test]
    fn two_warps_on_two_schedulers_run_in_parallel() {
        // tiny() has 2 schedulers; warps 0,1 land on different schedulers.
        let p = || WarpProgram::new(vec![WarpInst::Alu(50)]);
        let (insts, cycles) = run_alu_only(vec![p(), p()], &cfg());
        assert_eq!(insts, 100);
        assert_eq!(cycles, 50, "two schedulers issue in parallel");
    }

    #[test]
    fn two_warps_same_scheduler_serialize() {
        // Warps 0 and 2 both map to scheduler 0 (w % 2).
        let p = || WarpProgram::new(vec![WarpInst::Alu(50)]);
        let progs = vec![p(), WarpProgram::new(vec![]), p()];
        let (insts, cycles) = run_alu_only(progs, &cfg());
        assert_eq!(insts, 100);
        assert_eq!(cycles, 100, "same scheduler serializes warps");
    }

    #[test]
    fn load_blocks_warp_until_completion() {
        let p = WarpProgram::new(vec![
            WarpInst::Load(vec![(10, 0b1111), (11, 0b1111)]),
            WarpInst::Alu(1),
        ]);
        let mut core = SimtCore::new(0, &cfg(), vec![p]);
        let mut out = IssueBatch::default();
        core.tick(0, &mut out);
        assert_eq!(out.requests.len(), 2);
        assert_eq!(out.requests[0].1, 2, "load inst tagged with request count");
        assert!(core.next_ready_cycle().is_none(), "warp blocked");
        assert!(!core.all_done());

        // No issue while blocked.
        let mut out2 = IssueBatch::default();
        core.tick(1, &mut out2);
        assert_eq!(out2.insts_issued, 0);
        // Cycle 0: scheduler 1 (no warps) stalled; cycle 1: both stalled.
        assert_eq!(core.stall_cycles, 3);

        // Wake at 100; warp issues the trailing ALU inst at 101.
        core.load_complete(0, 100);
        assert_eq!(core.next_ready_cycle(), Some(101));
        let mut out3 = IssueBatch::default();
        core.tick(101, &mut out3);
        assert_eq!(out3.insts_issued, 1);
        assert!(core.all_done());
    }

    /// `stall_cycles` must not depend on the clock cadence: driving the
    /// same core through every cycle (the reference clock) or only
    /// through the cycles an event-driven engine visits (issue points
    /// and wakes — the skipped stretch is batch-charged on the next
    /// tick) yields the same count.
    #[test]
    fn stall_cycles_agree_between_clock_cadences() {
        let drive = |cycles: &[u64]| {
            let p = WarpProgram::new(vec![
                WarpInst::Load(vec![(7, 0b1111)]),
                WarpInst::Alu(1),
            ]);
            let mut core = SimtCore::new(0, &cfg(), vec![p]);
            let mut out = IssueBatch::default();
            for &c in cycles {
                if c == 50 {
                    // The engine delivers due wakes before ticking.
                    core.load_complete(0, 50);
                }
                core.tick(c, &mut out);
            }
            assert!(core.all_done(), "drive must retire the warp");
            core.stall_cycles
        };
        let reference: Vec<u64> = (0..=51).collect();
        // What an event-driven engine visits: the load issue at 0, the
        // post-issue hint at 1, the wake at 50, the ALU issue at 51.
        let jumped = [0, 1, 50, 51];
        assert_eq!(drive(&reference), drive(&jumped));
    }

    #[test]
    fn store_does_not_block() {
        let p = WarpProgram::new(vec![
            WarpInst::Store(vec![(5, 0b0001)]),
            WarpInst::Alu(1),
        ]);
        let mut core = SimtCore::new(0, &cfg(), vec![p]);
        let mut out = IssueBatch::default();
        core.tick(0, &mut out);
        assert_eq!(out.requests.len(), 1);
        assert_eq!(out.requests[0].0.kind, AccessKind::Store);
        let mut out2 = IssueBatch::default();
        core.tick(1, &mut out2);
        assert_eq!(out2.insts_issued, 1, "ALU issues right after the store");
        assert!(core.all_done());
    }

    #[test]
    fn trailing_load_retires_warp_on_wake() {
        let p = WarpProgram::new(vec![WarpInst::Load(vec![(1, 1)])]);
        let mut core = SimtCore::new(0, &cfg(), vec![p]);
        let mut out = IssueBatch::default();
        core.tick(0, &mut out);
        assert!(!core.all_done());
        core.load_complete(0, 50);
        assert!(core.all_done(), "last-inst load retires on completion");
    }

    #[test]
    fn gto_prefers_current_warp() {
        // Warp 0: Alu(3). Warp 2 (same scheduler): Alu(3).
        // GTO sticks with warp 0 for all 3 insts before switching.
        let progs = vec![
            WarpProgram::new(vec![WarpInst::Alu(3), WarpInst::Load(vec![(1, 1)])]),
            WarpProgram::new(vec![]),
            WarpProgram::new(vec![WarpInst::Alu(3)]),
        ];
        let mut core = SimtCore::new(0, &cfg(), progs);
        // After 3 cycles, warp 0 must be at its load (pc=1), warp 2 untouched.
        for c in 0..3 {
            let mut out = IssueBatch::default();
            core.tick(c, &mut out);
        }
        let mut out = IssueBatch::default();
        core.tick(3, &mut out);
        assert_eq!(out.requests.len(), 1, "warp 0's load issued before warp 2 ran");
    }

    #[test]
    fn request_ids_are_unique_across_cores() {
        let p = || WarpProgram::new(vec![WarpInst::Load(vec![(1, 1)])]);
        let mut c0 = SimtCore::new(0, &cfg(), vec![p()]);
        let mut c1 = SimtCore::new(1, &cfg(), vec![p()]);
        let mut o0 = IssueBatch::default();
        let mut o1 = IssueBatch::default();
        c0.tick(0, &mut o0);
        c1.tick(0, &mut o1);
        assert_ne!(o0.requests[0].0.id, o1.requests[0].0.id);
    }

    #[test]
    fn core_partition_split_and_mapping() {
        let parts = CorePartition::split(8, &[3, 5]).unwrap();
        assert_eq!(parts[0], CorePartition { first: 0, count: 3 });
        assert_eq!(parts[1], CorePartition { first: 3, count: 5 });
        assert!(parts[1].contains(3) && parts[1].contains(7) && !parts[1].contains(2));
        assert_eq!(parts[1].global(2), 5);
        assert_eq!(parts[1].local(5), 2);
        assert!(CorePartition::split(8, &[4, 5]).is_err(), "oversubscribed");
        assert!(CorePartition::split(8, &[0, 4]).is_err(), "zero-size");
        // Under-subscription leaves tail cores idle.
        assert_eq!(CorePartition::split(8, &[2]).unwrap()[0].count, 2);
    }

    #[test]
    fn core_partition_even_distributes_remainder() {
        let parts = CorePartition::even(30, 4).unwrap();
        let sizes: Vec<usize> = parts.iter().map(|p| p.count).collect();
        assert_eq!(sizes, vec![8, 8, 7, 7]);
        assert_eq!(parts.last().unwrap().end(), 30);
        assert!(CorePartition::even(4, 0).is_err());
        assert!(CorePartition::even(2, 3).is_err());
    }
}
