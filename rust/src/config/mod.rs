//! GPU configuration system — Table II of the paper, as data.
//!
//! Every experiment builds a [`GpuConfig`] (defaults = the paper's
//! simulated GPU), optionally overrides fields, validates, and hands it to
//! the engine.  Configs round-trip through JSON so sweeps can be driven
//! from files, and every derived geometry quantity (sets, banks, slices)
//! is computed here once, not scattered through the simulator.

use crate::util::json::Json;

/// Which L1 organization the cluster runs (§II/§III of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum L1ArchKind {
    /// Conventional per-core private L1 (the normalization baseline).
    Private,
    /// Remote-sharing: private L1s + probe ring between cores
    /// (Dublish et al. cooperative caching; optional probe predictor per
    /// Ibrahim PACT'19).
    RemoteSharing,
    /// Decoupled-sharing: cluster L1s address-sliced, every access routed
    /// to the line's home slice (Ibrahim PACT'20 / HPCA'21).
    DecoupledSharing,
    /// The paper's contribution: aggregated tag array + remote-shared data.
    Ata,
    /// ATA probing plus CIAO-style interference-aware bypass: remote hits
    /// whose holder-side banks/fabric ports are contended are redirected
    /// to L2 instead of queueing on the peer cache (see PAPERS.md, CIAO).
    AtaBypass,
}

impl L1ArchKind {
    pub const ALL: [L1ArchKind; 5] = [
        L1ArchKind::Private,
        L1ArchKind::RemoteSharing,
        L1ArchKind::DecoupledSharing,
        L1ArchKind::Ata,
        L1ArchKind::AtaBypass,
    ];

    /// The paper's original four-organization design space (the golden
    /// set the equivalence fixtures pin; excludes later extensions).
    pub const PAPER: [L1ArchKind; 4] = [
        L1ArchKind::Private,
        L1ArchKind::RemoteSharing,
        L1ArchKind::DecoupledSharing,
        L1ArchKind::Ata,
    ];

    pub fn name(self) -> &'static str {
        match self {
            L1ArchKind::Private => "private",
            L1ArchKind::RemoteSharing => "remote",
            L1ArchKind::DecoupledSharing => "decoupled",
            L1ArchKind::Ata => "ata",
            L1ArchKind::AtaBypass => "ata-bypass",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "private" => Some(L1ArchKind::Private),
            "remote" | "remote-sharing" => Some(L1ArchKind::RemoteSharing),
            "decoupled" | "decoupled-sharing" => Some(L1ArchKind::DecoupledSharing),
            "ata" | "ata-cache" => Some(L1ArchKind::Ata),
            "ata-bypass" | "ata-bypass-cache" => Some(L1ArchKind::AtaBypass),
            _ => None,
        }
    }
}

/// L1 write handling.  The paper processes writes only in the source
/// core's local cache with a dirty bit (§III-C); GPGPU-Sim's conventional
/// policy is write-through.  Both are modeled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WritePolicy {
    /// Write-through, no-allocate (conventional GPU L1).
    WriteThrough,
    /// Paper policy: allocate/write in local cache only, dirty bit set;
    /// remote readers that hit a dirty line fall back to L2.
    WriteBackLocal,
}

/// L1 cache geometry + timing (per core). Defaults = Table II row 2.
#[derive(Debug, Clone, PartialEq)]
pub struct L1Config {
    pub size_bytes: usize,
    pub assoc: usize,
    pub banks: usize,
    pub line_bytes: usize,
    pub sector_bytes: usize,
    pub latency: u32,
    pub mshr_entries: usize,
    pub mshr_merges: usize,
    /// Ports a single data-array bank serves per cycle.
    pub bank_ports: usize,
    pub write_policy: WritePolicy,
}

impl Default for L1Config {
    fn default() -> Self {
        L1Config {
            size_bytes: 64 * 1024,
            assoc: 64,
            banks: 4,
            line_bytes: 128,
            sector_bytes: 32,
            latency: 32,
            mshr_entries: 64,
            mshr_merges: 8,
            bank_ports: 1,
            write_policy: WritePolicy::WriteBackLocal,
        }
    }
}

impl L1Config {
    pub fn lines(&self) -> usize {
        self.size_bytes / self.line_bytes
    }
    pub fn sets(&self) -> usize {
        self.lines() / self.assoc
    }
    pub fn sectors_per_line(&self) -> usize {
        self.line_bytes / self.sector_bytes
    }
}

/// L2 geometry + timing. Defaults = Table II row 3 (24 sub-partitions of
/// 128 KiB, 16-way → 3 MiB total).
#[derive(Debug, Clone, PartialEq)]
pub struct L2Config {
    pub slices: usize,
    pub slice_size_bytes: usize,
    pub assoc: usize,
    pub line_bytes: usize,
    pub sector_bytes: usize,
    pub latency: u32,
    pub mshr_entries: usize,
    pub mshr_merges: usize,
}

impl Default for L2Config {
    fn default() -> Self {
        L2Config {
            slices: 24,
            slice_size_bytes: 128 * 1024,
            assoc: 16,
            line_bytes: 128,
            sector_bytes: 32,
            latency: 188,
            mshr_entries: 128,
            mshr_merges: 16,
        }
    }
}

impl L2Config {
    pub fn total_bytes(&self) -> usize {
        self.slices * self.slice_size_bytes
    }
    pub fn sets_per_slice(&self) -> usize {
        self.slice_size_bytes / (self.line_bytes * self.assoc)
    }
}

/// DRAM timing in *memory-clock* cycles (Table II row 4).
#[derive(Debug, Clone, PartialEq)]
pub struct DramConfig {
    pub controllers: usize,
    pub banks_per_controller: usize,
    pub clock_ghz: f64,
    pub t_cl: u32,
    pub t_rp: u32,
    pub t_rc: u32,
    pub t_ras: u32,
    pub t_ccd: u32,
    pub t_rcd: u32,
    pub t_rrd: u32,
    pub t_cdlr: u32,
    pub t_wr: u32,
    /// Burst length in memory cycles for one 32B sector transfer.
    pub burst_cycles: u32,
    /// Per-controller request queue depth.
    pub queue_depth: usize,
}

impl Default for DramConfig {
    fn default() -> Self {
        DramConfig {
            controllers: 12,
            banks_per_controller: 16,
            clock_ghz: 3.5,
            t_cl: 20,
            t_rp: 20,
            t_rc: 62,
            t_ras: 50,
            t_ccd: 4,
            t_rcd: 20,
            t_rrd: 10,
            t_cdlr: 9,
            t_wr: 20,
            burst_cycles: 4,
            queue_depth: 64,
        }
    }
}

/// Interconnect (cores ↔ L2 slices): Table II row 5.
#[derive(Debug, Clone, PartialEq)]
pub struct NocConfig {
    pub flit_bytes: usize,
    pub in_buffer_flits: usize,
    pub out_buffer_flits: usize,
    /// Crossbar traversal latency in core cycles.
    pub latency: u32,
    /// iSLIP arbitration iterations per cycle.
    pub islip_iters: usize,
}

impl Default for NocConfig {
    fn default() -> Self {
        NocConfig {
            flit_bytes: 40,
            in_buffer_flits: 512,
            out_buffer_flits: 512,
            latency: 2,
            islip_iters: 2,
        }
    }
}

/// Parameters specific to the shared-L1 organizations.
#[derive(Debug, Clone, PartialEq)]
pub struct SharingConfig {
    /// Ring hop latency (cycles) for remote-sharing probes/data.
    pub ring_hop_latency: u32,
    /// Ring link width in bytes/cycle (data serialization).
    pub ring_width_bytes: usize,
    /// Remote-sharing: enable the PACT'19-style presence predictor.
    pub probe_predictor: bool,
    /// Predictor accuracy model (probability a miss is correctly predicted
    /// absent and skips the probe round-trip).
    pub predictor_accuracy: f64,
    /// Intra-cluster crossbar latency for decoupled/ATA data access.
    pub cluster_xbar_latency: u32,
    /// Intra-cluster crossbar: ports per L1 data array for remote readers.
    pub remote_ports: usize,
    /// ATA aggregated-tag-array lookup latency (cycles) added in front of
    /// every access (the decoupled tag pipeline of §III-B).
    pub ata_tag_latency: u32,
    /// ATA: comparator groups per tag array (requests compared in
    /// parallel per cycle); the paper provisions one group per core.
    pub ata_comparator_groups: usize,
    /// Probability model for “remote line is dirty” fallback (§III-C says
    /// this is very rare; it is measured, not assumed, when the write
    /// policy is WriteBackLocal).
    pub fill_local_on_remote_hit: bool,
    /// `ata-bypass` only: a remote hit is redirected to L2 when the
    /// holder-side pressure estimate (holder data-bank backlog + crossbar
    /// port backlog, in cycles) exceeds this threshold.  CIAO-style
    /// interference-aware bypass; 0 bypasses every contended remote hit.
    pub bypass_backlog_threshold: u64,
    /// Host-performance knob for the ATA-family organizations: answer
    /// aggregated-tag probes from the incrementally maintained per-cluster
    /// residency index (O(1) hash lookup) instead of peeking every peer
    /// cache (O(cluster) scan).  Simulated metrics are byte-identical
    /// either way — only wall clock moves (pinned by the differential and
    /// byte-identity tests in `rust/tests/residency_differential.rs`).
    pub residency_index: bool,
}

impl Default for SharingConfig {
    fn default() -> Self {
        SharingConfig {
            ring_hop_latency: 2,
            ring_width_bytes: 32,
            probe_predictor: false,
            predictor_accuracy: 0.8,
            cluster_xbar_latency: 4,
            remote_ports: 1,
            ata_tag_latency: 2,
            ata_comparator_groups: 10,
            fill_local_on_remote_hit: true,
            bypass_backlog_threshold: 8,
            residency_index: true,
        }
    }
}

/// Deterministic fault injection for exercising the failure path
/// (`--inject`).  `None` (the default) is a no-op; the other kinds make
/// the run fail with the matching typed `SimError` at a point that is a
/// pure function of the simulated request stream, so the *failure* obeys
/// the same byte-identity contract as results do.  Exists for the
/// failure-determinism tests and the CI poisoned-grid smoke; never set
/// by a real experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// No injection (the only value real experiments use).
    None,
    /// Swallow the first load-completion wake: the issuing warp blocks
    /// forever and the run ends in `SimError::Deadlock`.
    Deadlock,
    /// Re-schedule every delivered wake instead of completing the load:
    /// the clock keeps advancing but nothing retires, so the
    /// forward-progress watchdog ends the run in `SimError::Livelock`.
    Livelock,
    /// `panic!` at run start — exercises `catch_unwind` containment in
    /// the job runner (`SimError` never sees this one).
    Panic,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::None => "none",
            FaultKind::Deadlock => "deadlock",
            FaultKind::Livelock => "livelock",
            FaultKind::Panic => "panic",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "none" => Some(FaultKind::None),
            "deadlock" => Some(FaultKind::Deadlock),
            "livelock" => Some(FaultKind::Livelock),
            "panic" => Some(FaultKind::Panic),
            _ => None,
        }
    }
}

/// Host simulation-strategy knobs.  Nothing in this section may change a
/// simulated metric — only how fast the host machine reaches it.  That
/// contract is enforced byte-for-byte by `rust/tests/event_determinism.rs`
/// and the CI `--event-driven off` cmp smoke.  (The two failure knobs —
/// `fault` and `job_timeout_s` — can *abort* a run with a typed error,
/// but can never change the metrics of a run that completes.)
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// Event-driven clock advance: when no core can issue this cycle, jump
    /// `now` straight to the next-event horizon (earliest core wake or
    /// pending load completion) instead of ticking through the idle
    /// stretch cycle by cycle.  `false` selects the cycle-by-cycle
    /// reference mode the differential tests compare against.  Simulated
    /// metrics are byte-identical either way — only wall clock moves.
    pub event_driven: bool,
    /// Cluster shards the cycle loop runs across (`--shards`).  Each shard
    /// owns a contiguous cluster range — its cores, SIMT issue, and wake
    /// calendar — and ticks them on its own host thread between the
    /// deterministic epoch barriers of `engine::shard`; the shared
    /// L1/NoC/L2/DRAM walk stays serialized in canonical request order at
    /// the barrier.  `1` (the default) selects the unsharded loop;
    /// values above the cluster count clamp to it.  Simulated metrics are
    /// byte-identical at any shard count — only wall clock moves (pinned
    /// by `rust/tests/shard_determinism.rs` and the CI cmp smoke).
    /// Sharding stays opt-in until a toolchain-equipped session measures
    /// the crossover against the per-epoch barrier cost.
    pub shards: usize,
    /// Persistent walk workers for phase B2 of the phased memory walk
    /// (`--mem-workers`).  Each worker exclusively owns a contiguous run
    /// of L2 slices during the per-slice half of the walk
    /// (`l2::walk::WalkPool`); the cross-slice front end (B1), DRAM
    /// admission, and the merge pass (B3) stay serialized in canonical
    /// request order on the coordinator.  `1` (the default) walks
    /// serially with no threads spawned; values above the slice count
    /// clamp to it.  Composes with `shards`.  Simulated metrics are
    /// byte-identical at any worker count — only wall clock moves (pinned
    /// by `rust/tests/memwalk_determinism.rs` and the CI cmp smoke).
    pub mem_workers: usize,
    /// Deterministic fault injection (`--inject`, testing only).  See
    /// [`FaultKind`]; `None` is the default and the only value real
    /// experiments use.
    pub fault: FaultKind,
    /// Opt-in host wall-clock budget per `Engine::run`/`run_multi` call
    /// (`--job-timeout-s`).  `0` (the default) disables the watchdog;
    /// a nonzero value aborts the run with `SimError::HostTimeout` once
    /// the budget expires.  Inherently host-dependent — the one failure
    /// kind outside the byte-identity contract.
    pub job_timeout_s: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            event_driven: true,
            shards: 1,
            mem_workers: 1,
            fault: FaultKind::None,
            job_timeout_s: 0,
        }
    }
}

/// Top-level simulated GPU (Table II defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuConfig {
    pub cores: usize,
    pub clusters: usize,
    pub core_clock_ghz: f64,
    pub schedulers_per_core: usize,
    pub max_warps_per_core: usize,
    /// Warp instructions issued per scheduler per cycle.
    pub issue_width: usize,
    pub l1: L1Config,
    pub l2: L2Config,
    pub dram: DramConfig,
    pub noc: NocConfig,
    pub sharing: SharingConfig,
    pub engine: EngineConfig,
    pub l1_arch: L1ArchKind,
    pub seed: u64,
}

impl Default for GpuConfig {
    fn default() -> Self {
        GpuConfig {
            cores: 30,
            clusters: 3,
            core_clock_ghz: 1.365,
            schedulers_per_core: 4,
            max_warps_per_core: 64,
            issue_width: 1,
            l1: L1Config::default(),
            l2: L2Config::default(),
            dram: DramConfig::default(),
            noc: NocConfig::default(),
            sharing: SharingConfig::default(),
            engine: EngineConfig::default(),
            l1_arch: L1ArchKind::Private,
            seed: 0xA7A_CACE,
        }
    }
}

/// Why a configuration could not be built, loaded, or validated.
#[derive(Debug)]
pub enum ConfigError {
    Invalid(String),
    Json(crate::util::json::JsonError),
    Io(std::io::Error),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Invalid(m) => write!(f, "invalid config: {m}"),
            ConfigError::Json(e) => write!(f, "json: {e}"),
            ConfigError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Invalid(_) => None,
            ConfigError::Json(e) => Some(e),
            ConfigError::Io(e) => Some(e),
        }
    }
}

impl From<crate::util::json::JsonError> for ConfigError {
    fn from(e: crate::util::json::JsonError) -> Self {
        ConfigError::Json(e)
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

impl GpuConfig {
    /// Paper configuration with a given L1 organization.
    pub fn paper(arch: L1ArchKind) -> Self {
        GpuConfig {
            l1_arch: arch,
            ..Default::default()
        }
    }

    /// A scaled-down configuration for fast unit/integration tests:
    /// 8 cores in 2 clusters, 8 KiB L1s, shallow memory system.
    pub fn tiny(arch: L1ArchKind) -> Self {
        GpuConfig {
            cores: 8,
            clusters: 2,
            schedulers_per_core: 2,
            max_warps_per_core: 8,
            l1: L1Config {
                size_bytes: 8 * 1024,
                assoc: 16,
                banks: 2,
                mshr_entries: 16,
                mshr_merges: 4,
                ..Default::default()
            },
            l2: L2Config {
                slices: 4,
                slice_size_bytes: 32 * 1024,
                ..Default::default()
            },
            dram: DramConfig {
                controllers: 2,
                banks_per_controller: 4,
                ..Default::default()
            },
            sharing: SharingConfig {
                ata_comparator_groups: 4,
                ..Default::default()
            },
            l1_arch: arch,
            ..Default::default()
        }
    }

    pub fn cores_per_cluster(&self) -> usize {
        self.cores / self.clusters
    }

    /// DRAM-to-core clock ratio (used to convert DRAM timings into core
    /// cycles — the engine runs a single core-clock domain).
    pub fn dram_clock_ratio(&self) -> f64 {
        self.dram.clock_ghz / self.core_clock_ghz
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let fail = |m: String| Err(ConfigError::Invalid(m));
        if self.cores == 0 || self.clusters == 0 {
            return fail("cores/clusters must be > 0".into());
        }
        if self.cores % self.clusters != 0 {
            return fail(format!(
                "cores ({}) must divide evenly into clusters ({})",
                self.cores, self.clusters
            ));
        }
        if !self.l1.lines().is_power_of_two() || self.l1.sets() == 0 {
            return fail("L1 lines must be a power of two".into());
        }
        if self.l1.lines() % self.l1.assoc != 0 {
            return fail("L1 assoc must divide line count".into());
        }
        if self.l1.line_bytes % self.l1.sector_bytes != 0 {
            return fail("sector size must divide line size".into());
        }
        if self.l1.sectors_per_line() > 8 {
            return fail("at most 8 sectors per line (mask is u8)".into());
        }
        if !self.l1.sets().is_power_of_two() {
            return fail("L1 set count must be a power of two".into());
        }
        if !self.l1.banks.is_power_of_two() {
            return fail("L1 bank count must be a power of two".into());
        }
        if self.l2.sets_per_slice() == 0 || !self.l2.sets_per_slice().is_power_of_two() {
            return fail("L2 sets/slice must be a power of two".into());
        }
        if self.cores_per_cluster() > 64 {
            return fail(format!(
                "at most 64 cores per cluster ({} requested — residency \
                 holder masks are u64)",
                self.cores_per_cluster()
            ));
        }
        if self.sharing.ata_comparator_groups < self.cores_per_cluster() {
            return fail(format!(
                "ATA comparator groups ({}) must cover the cluster ({})",
                self.sharing.ata_comparator_groups,
                self.cores_per_cluster()
            ));
        }
        if self.l1.mshr_entries == 0 || self.l2.mshr_entries == 0 {
            return fail("MSHR entries must be > 0".into());
        }
        if self.engine.shards == 0 {
            return fail("engine.shards must be > 0 (1 = unsharded loop)".into());
        }
        if self.engine.mem_workers == 0 {
            return fail("engine.mem_workers must be > 0 (1 = serial walk)".into());
        }
        Ok(())
    }

    // -- JSON round-trip ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cores", self.cores.into()),
            ("clusters", self.clusters.into()),
            ("core_clock_ghz", self.core_clock_ghz.into()),
            ("schedulers_per_core", self.schedulers_per_core.into()),
            ("max_warps_per_core", self.max_warps_per_core.into()),
            ("issue_width", self.issue_width.into()),
            ("l1_arch", self.l1_arch.name().into()),
            ("seed", self.seed.into()),
            (
                "l1",
                Json::obj(vec![
                    ("size_bytes", self.l1.size_bytes.into()),
                    ("assoc", self.l1.assoc.into()),
                    ("banks", self.l1.banks.into()),
                    ("line_bytes", self.l1.line_bytes.into()),
                    ("sector_bytes", self.l1.sector_bytes.into()),
                    ("latency", (self.l1.latency as u64).into()),
                    ("mshr_entries", self.l1.mshr_entries.into()),
                    ("mshr_merges", self.l1.mshr_merges.into()),
                    ("bank_ports", self.l1.bank_ports.into()),
                    (
                        "write_policy",
                        match self.l1.write_policy {
                            WritePolicy::WriteThrough => "write-through",
                            WritePolicy::WriteBackLocal => "write-back-local",
                        }
                        .into(),
                    ),
                ]),
            ),
            (
                "l2",
                Json::obj(vec![
                    ("slices", self.l2.slices.into()),
                    ("slice_size_bytes", self.l2.slice_size_bytes.into()),
                    ("assoc", self.l2.assoc.into()),
                    ("line_bytes", self.l2.line_bytes.into()),
                    ("sector_bytes", self.l2.sector_bytes.into()),
                    ("latency", (self.l2.latency as u64).into()),
                    ("mshr_entries", self.l2.mshr_entries.into()),
                    ("mshr_merges", self.l2.mshr_merges.into()),
                ]),
            ),
            (
                "dram",
                Json::obj(vec![
                    ("controllers", self.dram.controllers.into()),
                    ("banks_per_controller", self.dram.banks_per_controller.into()),
                    ("clock_ghz", self.dram.clock_ghz.into()),
                    ("t_cl", (self.dram.t_cl as u64).into()),
                    ("t_rp", (self.dram.t_rp as u64).into()),
                    ("t_rc", (self.dram.t_rc as u64).into()),
                    ("t_ras", (self.dram.t_ras as u64).into()),
                    ("t_ccd", (self.dram.t_ccd as u64).into()),
                    ("t_rcd", (self.dram.t_rcd as u64).into()),
                    ("t_rrd", (self.dram.t_rrd as u64).into()),
                    ("t_cdlr", (self.dram.t_cdlr as u64).into()),
                    ("t_wr", (self.dram.t_wr as u64).into()),
                    ("burst_cycles", (self.dram.burst_cycles as u64).into()),
                    ("queue_depth", self.dram.queue_depth.into()),
                ]),
            ),
            (
                "noc",
                Json::obj(vec![
                    ("flit_bytes", self.noc.flit_bytes.into()),
                    ("in_buffer_flits", self.noc.in_buffer_flits.into()),
                    ("out_buffer_flits", self.noc.out_buffer_flits.into()),
                    ("latency", (self.noc.latency as u64).into()),
                    ("islip_iters", self.noc.islip_iters.into()),
                ]),
            ),
            (
                "sharing",
                Json::obj(vec![
                    ("ring_hop_latency", (self.sharing.ring_hop_latency as u64).into()),
                    ("ring_width_bytes", self.sharing.ring_width_bytes.into()),
                    ("probe_predictor", self.sharing.probe_predictor.into()),
                    ("predictor_accuracy", self.sharing.predictor_accuracy.into()),
                    (
                        "cluster_xbar_latency",
                        (self.sharing.cluster_xbar_latency as u64).into(),
                    ),
                    ("remote_ports", self.sharing.remote_ports.into()),
                    ("ata_tag_latency", (self.sharing.ata_tag_latency as u64).into()),
                    (
                        "ata_comparator_groups",
                        self.sharing.ata_comparator_groups.into(),
                    ),
                    (
                        "fill_local_on_remote_hit",
                        self.sharing.fill_local_on_remote_hit.into(),
                    ),
                    (
                        "bypass_backlog_threshold",
                        self.sharing.bypass_backlog_threshold.into(),
                    ),
                    ("residency_index", self.sharing.residency_index.into()),
                ]),
            ),
            (
                "engine",
                Json::obj(vec![
                    ("event_driven", self.engine.event_driven.into()),
                    ("shards", self.engine.shards.into()),
                    ("mem_workers", self.engine.mem_workers.into()),
                    ("fault", self.engine.fault.name().into()),
                    ("job_timeout_s", self.engine.job_timeout_s.into()),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        let mut cfg = GpuConfig::default();
        let g_usize = |j: &Json, k: &str, d: usize| j.get(k).and_then(Json::as_usize).unwrap_or(d);
        let g_u32 = |j: &Json, k: &str, d: u32| {
            j.get(k).and_then(Json::as_u64).map(|x| x as u32).unwrap_or(d)
        };
        let g_f64 = |j: &Json, k: &str, d: f64| j.get(k).and_then(Json::as_f64).unwrap_or(d);
        let g_bool = |j: &Json, k: &str, d: bool| j.get(k).and_then(Json::as_bool).unwrap_or(d);

        cfg.cores = g_usize(j, "cores", cfg.cores);
        cfg.clusters = g_usize(j, "clusters", cfg.clusters);
        cfg.core_clock_ghz = g_f64(j, "core_clock_ghz", cfg.core_clock_ghz);
        cfg.schedulers_per_core = g_usize(j, "schedulers_per_core", cfg.schedulers_per_core);
        cfg.max_warps_per_core = g_usize(j, "max_warps_per_core", cfg.max_warps_per_core);
        cfg.issue_width = g_usize(j, "issue_width", cfg.issue_width);
        cfg.seed = j.get("seed").and_then(Json::as_u64).unwrap_or(cfg.seed);
        if let Some(name) = j.get("l1_arch").and_then(Json::as_str) {
            cfg.l1_arch = L1ArchKind::from_name(name)
                .ok_or_else(|| ConfigError::Invalid(format!("unknown l1_arch '{name}'")))?;
        }
        if let Some(l1) = j.get("l1") {
            cfg.l1.size_bytes = g_usize(l1, "size_bytes", cfg.l1.size_bytes);
            cfg.l1.assoc = g_usize(l1, "assoc", cfg.l1.assoc);
            cfg.l1.banks = g_usize(l1, "banks", cfg.l1.banks);
            cfg.l1.line_bytes = g_usize(l1, "line_bytes", cfg.l1.line_bytes);
            cfg.l1.sector_bytes = g_usize(l1, "sector_bytes", cfg.l1.sector_bytes);
            cfg.l1.latency = g_u32(l1, "latency", cfg.l1.latency);
            cfg.l1.mshr_entries = g_usize(l1, "mshr_entries", cfg.l1.mshr_entries);
            cfg.l1.mshr_merges = g_usize(l1, "mshr_merges", cfg.l1.mshr_merges);
            cfg.l1.bank_ports = g_usize(l1, "bank_ports", cfg.l1.bank_ports);
            if let Some(wp) = l1.get("write_policy").and_then(Json::as_str) {
                cfg.l1.write_policy = match wp {
                    "write-through" => WritePolicy::WriteThrough,
                    "write-back-local" => WritePolicy::WriteBackLocal,
                    other => {
                        return Err(ConfigError::Invalid(format!("unknown write_policy '{other}'")))
                    }
                };
            }
        }
        if let Some(l2) = j.get("l2") {
            cfg.l2.slices = g_usize(l2, "slices", cfg.l2.slices);
            cfg.l2.slice_size_bytes = g_usize(l2, "slice_size_bytes", cfg.l2.slice_size_bytes);
            cfg.l2.assoc = g_usize(l2, "assoc", cfg.l2.assoc);
            cfg.l2.line_bytes = g_usize(l2, "line_bytes", cfg.l2.line_bytes);
            cfg.l2.sector_bytes = g_usize(l2, "sector_bytes", cfg.l2.sector_bytes);
            cfg.l2.latency = g_u32(l2, "latency", cfg.l2.latency);
            cfg.l2.mshr_entries = g_usize(l2, "mshr_entries", cfg.l2.mshr_entries);
            cfg.l2.mshr_merges = g_usize(l2, "mshr_merges", cfg.l2.mshr_merges);
        }
        if let Some(d) = j.get("dram") {
            cfg.dram.controllers = g_usize(d, "controllers", cfg.dram.controllers);
            cfg.dram.banks_per_controller =
                g_usize(d, "banks_per_controller", cfg.dram.banks_per_controller);
            cfg.dram.clock_ghz = g_f64(d, "clock_ghz", cfg.dram.clock_ghz);
            cfg.dram.t_cl = g_u32(d, "t_cl", cfg.dram.t_cl);
            cfg.dram.t_rp = g_u32(d, "t_rp", cfg.dram.t_rp);
            cfg.dram.t_rc = g_u32(d, "t_rc", cfg.dram.t_rc);
            cfg.dram.t_ras = g_u32(d, "t_ras", cfg.dram.t_ras);
            cfg.dram.t_ccd = g_u32(d, "t_ccd", cfg.dram.t_ccd);
            cfg.dram.t_rcd = g_u32(d, "t_rcd", cfg.dram.t_rcd);
            cfg.dram.t_rrd = g_u32(d, "t_rrd", cfg.dram.t_rrd);
            cfg.dram.t_cdlr = g_u32(d, "t_cdlr", cfg.dram.t_cdlr);
            cfg.dram.t_wr = g_u32(d, "t_wr", cfg.dram.t_wr);
            cfg.dram.burst_cycles = g_u32(d, "burst_cycles", cfg.dram.burst_cycles);
            cfg.dram.queue_depth = g_usize(d, "queue_depth", cfg.dram.queue_depth);
        }
        if let Some(n) = j.get("noc") {
            cfg.noc.flit_bytes = g_usize(n, "flit_bytes", cfg.noc.flit_bytes);
            cfg.noc.in_buffer_flits = g_usize(n, "in_buffer_flits", cfg.noc.in_buffer_flits);
            cfg.noc.out_buffer_flits = g_usize(n, "out_buffer_flits", cfg.noc.out_buffer_flits);
            cfg.noc.latency = g_u32(n, "latency", cfg.noc.latency);
            cfg.noc.islip_iters = g_usize(n, "islip_iters", cfg.noc.islip_iters);
        }
        if let Some(s) = j.get("sharing") {
            cfg.sharing.ring_hop_latency = g_u32(s, "ring_hop_latency", cfg.sharing.ring_hop_latency);
            cfg.sharing.ring_width_bytes =
                g_usize(s, "ring_width_bytes", cfg.sharing.ring_width_bytes);
            cfg.sharing.probe_predictor = g_bool(s, "probe_predictor", cfg.sharing.probe_predictor);
            cfg.sharing.predictor_accuracy =
                g_f64(s, "predictor_accuracy", cfg.sharing.predictor_accuracy);
            cfg.sharing.cluster_xbar_latency =
                g_u32(s, "cluster_xbar_latency", cfg.sharing.cluster_xbar_latency);
            cfg.sharing.remote_ports = g_usize(s, "remote_ports", cfg.sharing.remote_ports);
            cfg.sharing.ata_tag_latency = g_u32(s, "ata_tag_latency", cfg.sharing.ata_tag_latency);
            cfg.sharing.ata_comparator_groups =
                g_usize(s, "ata_comparator_groups", cfg.sharing.ata_comparator_groups);
            cfg.sharing.fill_local_on_remote_hit =
                g_bool(s, "fill_local_on_remote_hit", cfg.sharing.fill_local_on_remote_hit);
            cfg.sharing.bypass_backlog_threshold = s
                .get("bypass_backlog_threshold")
                .and_then(Json::as_u64)
                .unwrap_or(cfg.sharing.bypass_backlog_threshold);
            cfg.sharing.residency_index =
                g_bool(s, "residency_index", cfg.sharing.residency_index);
        }
        if let Some(e) = j.get("engine") {
            cfg.engine.event_driven = g_bool(e, "event_driven", cfg.engine.event_driven);
            cfg.engine.shards = g_usize(e, "shards", cfg.engine.shards);
            cfg.engine.mem_workers = g_usize(e, "mem_workers", cfg.engine.mem_workers);
            if let Some(name) = e.get("fault").and_then(Json::as_str) {
                cfg.engine.fault = FaultKind::from_name(name)
                    .ok_or_else(|| ConfigError::Invalid(format!("unknown fault '{name}'")))?;
            }
            cfg.engine.job_timeout_s =
                e.get("job_timeout_s").and_then(Json::as_u64).unwrap_or(cfg.engine.job_timeout_s);
        }
        Ok(cfg)
    }

    pub fn save(&self, path: &str) -> Result<(), ConfigError> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    pub fn load(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        let cfg = Self::from_json(&Json::parse(&text)?)?;
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_matches_table2() {
        let cfg = GpuConfig::paper(L1ArchKind::Ata);
        assert_eq!(cfg.cores, 30);
        assert_eq!(cfg.clusters, 3);
        assert_eq!(cfg.cores_per_cluster(), 10);
        assert_eq!(cfg.l1.size_bytes, 64 * 1024);
        assert_eq!(cfg.l1.assoc, 64);
        assert_eq!(cfg.l1.sets(), 8);
        assert_eq!(cfg.l1.sectors_per_line(), 4);
        assert_eq!(cfg.l1.latency, 32);
        assert_eq!(cfg.l2.total_bytes(), 3 * 1024 * 1024);
        assert_eq!(cfg.l2.latency, 188);
        assert_eq!(cfg.l2.slices, 24);
        assert_eq!(cfg.dram.controllers, 12);
        assert_eq!(cfg.schedulers_per_core, 4);
        cfg.validate().unwrap();
    }

    #[test]
    fn tiny_config_validates_for_all_archs() {
        for arch in L1ArchKind::ALL {
            GpuConfig::tiny(arch).validate().unwrap();
        }
    }

    #[test]
    fn dram_clock_ratio() {
        let cfg = GpuConfig::default();
        assert!((cfg.dram_clock_ratio() - 3.5 / 1.365).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut cfg = GpuConfig::paper(L1ArchKind::DecoupledSharing);
        cfg.sharing.probe_predictor = true;
        cfg.sharing.residency_index = false;
        cfg.engine.event_driven = false;
        cfg.engine.shards = 3;
        cfg.engine.mem_workers = 5;
        cfg.engine.fault = FaultKind::Livelock;
        cfg.engine.job_timeout_s = 30;
        cfg.l1.write_policy = WritePolicy::WriteThrough;
        cfg.seed = 12345;
        let j = cfg.to_json();
        let back = GpuConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn validation_catches_bad_geometry() {
        let mut cfg = GpuConfig::default();
        cfg.cores = 31; // not divisible by 3 clusters
        assert!(cfg.validate().is_err());

        let mut cfg = GpuConfig::default();
        cfg.l1.sector_bytes = 48; // does not divide 128
        assert!(cfg.validate().is_err());

        let mut cfg = GpuConfig::default();
        cfg.sharing.ata_comparator_groups = 2; // cluster needs 10
        assert!(cfg.validate().is_err());

        let mut cfg = GpuConfig::default();
        cfg.engine.shards = 0; // 1 is the unsharded minimum
        assert!(cfg.validate().is_err());

        // Over-sharding is legal (the engine clamps to the cluster count).
        let mut cfg = GpuConfig::default();
        cfg.engine.shards = 64;
        cfg.validate().unwrap();

        let mut cfg = GpuConfig::default();
        cfg.engine.mem_workers = 0; // 1 is the serial-walk minimum
        assert!(cfg.validate().is_err());

        // Over-provisioning is legal (the pool clamps to the slice count).
        let mut cfg = GpuConfig::default();
        cfg.engine.mem_workers = 64;
        cfg.validate().unwrap();
    }

    #[test]
    fn fault_kind_names_roundtrip() {
        for k in [FaultKind::None, FaultKind::Deadlock, FaultKind::Livelock, FaultKind::Panic] {
            assert_eq!(FaultKind::from_name(k.name()), Some(k));
        }
        assert!(FaultKind::from_name("bogus").is_none());
        // An unknown fault in a config file is a hard parse error, not a
        // silent default — injection typos must not run clean.
        let j = Json::parse(r#"{"engine": {"fault": "bogus"}}"#).unwrap();
        assert!(GpuConfig::from_json(&j).is_err());
    }

    #[test]
    fn arch_kind_names_roundtrip() {
        for arch in L1ArchKind::ALL {
            assert_eq!(L1ArchKind::from_name(arch.name()), Some(arch));
        }
        assert_eq!(L1ArchKind::from_name("ata-cache"), Some(L1ArchKind::Ata));
        assert!(L1ArchKind::from_name("bogus").is_none());
    }

    #[test]
    fn file_roundtrip() {
        let cfg = GpuConfig::paper(L1ArchKind::Ata);
        let path = std::env::temp_dir().join("ata_cfg_test.json");
        let path = path.to_str().unwrap();
        cfg.save(path).unwrap();
        let back = GpuConfig::load(path).unwrap();
        assert_eq!(cfg, back);
        std::fs::remove_file(path).ok();
    }
}
