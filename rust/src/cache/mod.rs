//! Cache building blocks: sectored tag array, MSHRs, and a composed
//! `SectoredCache` used as the storage half of every L1 organization and
//! of the L2 slices.  Timing (bank contention, latencies) deliberately
//! lives in the *organization* layer (`l1arch`, `l2`) — the paper's whole
//! point is that the same SRAM arrays perform differently depending on how
//! tag lookup and data access are organized.

pub mod mshr;
pub mod tag_array;

pub use mshr::{Mshr, MshrOutcome};
pub use tag_array::{Eviction, Probe, TagArray};

use crate::config::L1Config;
use crate::mem::{LineAddr, SectorMask};

/// Storage state of one cache: tags + MSHRs (the data array carries no
/// simulated contents — the simulator is timing-only, like GPGPU-Sim's
/// performance model).
#[derive(Debug, Clone)]
pub struct SectoredCache {
    pub tags: TagArray,
    pub mshr: Mshr,
}

impl SectoredCache {
    pub fn from_l1(cfg: &L1Config) -> Self {
        SectoredCache {
            tags: TagArray::new(cfg.sets(), cfg.assoc),
            mshr: Mshr::new(cfg.mshr_entries, cfg.mshr_merges),
        }
    }

    pub fn new(sets: usize, assoc: usize, mshr_entries: usize, mshr_merges: usize) -> Self {
        SectoredCache {
            tags: TagArray::new(sets, assoc),
            mshr: Mshr::new(mshr_entries, mshr_merges),
        }
    }

    /// Probe without state change (aggregated-tag-array view of this cache).
    pub fn peek(&self, line: LineAddr, sectors: SectorMask) -> Probe {
        self.tags.peek(line, sectors)
    }

    /// Install a fill and release waiting requests.
    pub fn fill(
        &mut self,
        line: LineAddr,
        sectors: SectorMask,
    ) -> (Vec<crate::mem::MemRequest>, Option<Eviction>) {
        // lint: allow(tag-mutation-helper) — SectoredCache::fill IS the substrate the pipeline helpers call
        let evicted = self.tags.fill(line, sectors);
        let waiters = self.mshr.fill(line);
        (waiters, evicted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{AccessKind, MemRequest};

    fn req(id: u64, line: LineAddr) -> MemRequest {
        MemRequest {
            id,
            core: 0,
            warp: 0,
            inst: 0,
            line,
            sectors: 0b1111,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn from_l1_uses_table2_geometry() {
        let cfg = L1Config::default();
        let c = SectoredCache::from_l1(&cfg);
        assert_eq!(c.tags.sets(), 8);
        assert_eq!(c.tags.assoc(), 64);
    }

    #[test]
    fn fill_releases_mshr_waiters_and_installs_line() {
        let mut c = SectoredCache::new(8, 2, 4, 4);
        assert_eq!(c.peek(9, 0b1111), Probe::Miss);
        c.mshr.allocate(req(1, 9));
        c.mshr.allocate(req(2, 9));
        let (waiters, ev) = c.fill(9, 0b1111);
        assert_eq!(waiters.len(), 2);
        assert!(ev.is_none());
        assert!(matches!(c.peek(9, 0b1111), Probe::Hit { .. }));
    }

    #[test]
    fn property_fill_never_leaves_stale_sector() {
        // Property: after fill(line, s), peek(line, s) is a full Hit —
        // across random interleavings of fills and evictions.
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(99, 0);
        let mut c = SectoredCache::new(4, 2, 8, 8);
        for _ in 0..2000 {
            let line = rng.next_below(64) as u64;
            let sectors = (rng.next_below(15) + 1) as u8;
            c.fill(line, sectors);
            match c.peek(line, sectors) {
                Probe::Hit { .. } => {}
                other => panic!("stale after fill: line={line} sectors={sectors:#b} {other:?}"),
            }
        }
    }
}
