//! Sectored set-associative tag array with LRU replacement.
//!
//! Models the paper's 64 KiB / 64-way / 128 B-line / 32 B-sector L1 (and,
//! with different geometry, the L2 slices).  A *line* owns the tag; each
//! of its sectors has independent valid and dirty bits (Table II: sector
//! caches).  The tag array is the structure the paper decouples and
//! aggregates, so probing (`peek`) is separated from allocating
//! (`fill`) and LRU-updating (`touch`) — the aggregated tag array of
//! ATA-Cache peeks remote arrays without perturbing their state.

use crate::mem::decode;
use crate::mem::{LineAddr, SectorMask};

/// Result of a lookup against one tag array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Probe {
    /// Line present and every requested sector valid.
    Hit { way: u32, dirty: bool },
    /// Line present but some requested sectors invalid (sector miss —
    /// fetch only the missing sectors).
    SectorMiss { way: u32, missing: SectorMask },
    /// Line absent.
    Miss,
}

#[derive(Debug, Clone, Copy, Default)]
struct TagEntry {
    valid: bool,
    tag: u64,
    sector_valid: SectorMask,
    sector_dirty: SectorMask,
    last_use: u64,
}

/// Evicted-line information returned by `fill` whenever a resident line
/// is replaced.  `dirty_sectors == 0` marks a clean victim: callers that
/// generate write-back traffic must check it (only dirty sectors travel),
/// while residency bookkeeping needs *every* eviction to stay coherent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Eviction {
    pub line: LineAddr,
    pub dirty_sectors: SectorMask,
}

impl Eviction {
    /// Does this victim carry modified data that must be written back?
    #[inline]
    pub fn needs_writeback(&self) -> bool {
        self.dirty_sectors != 0
    }
}

#[derive(Debug, Clone)]
pub struct TagArray {
    sets: usize,
    assoc: usize,
    entries: Vec<TagEntry>, // sets × assoc, row-major
    /// Per-set presence filter: bit `mix(tag) & 63` set for every valid
    /// way.  `peek`/`lookup` reject misses in O(1) — the aggregated tag
    /// array probes 10 caches per request and ~90% are misses, so this is
    /// a large fraction of simulator time (EXPERIMENTS.md §Perf).
    filters: Vec<u64>,
    /// Monotone use-counter driving LRU (not wall-clock cycles, so two
    /// touches in one cycle still order deterministically).
    use_tick: u64,
}

#[inline]
fn filter_bit(tag: u64) -> u64 {
    // Cheap multiplicative mix; collisions only cost a wasted scan.
    1u64 << ((tag.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 58) & 63)
}

impl TagArray {
    pub fn new(sets: usize, assoc: usize) -> Self {
        assert!(sets.is_power_of_two() && assoc > 0);
        TagArray {
            sets,
            assoc,
            entries: vec![TagEntry::default(); sets * assoc],
            filters: vec![0; sets],
            use_tick: 0,
        }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }

    pub fn assoc(&self) -> usize {
        self.assoc
    }

    #[inline]
    fn row(&self, set: usize) -> &[TagEntry] {
        &self.entries[set * self.assoc..(set + 1) * self.assoc]
    }

    #[inline]
    fn row_mut(&mut self, set: usize) -> &mut [TagEntry] {
        &mut self.entries[set * self.assoc..(set + 1) * self.assoc]
    }

    /// Non-destructive probe: no LRU update, no allocation.  This is the
    /// operation the aggregated tag array performs in parallel across all
    /// cluster caches (§III-B).
    pub fn peek(&self, line: LineAddr, sectors: SectorMask) -> Probe {
        let set = decode::set_index(line, self.sets);
        let tag = decode::tag(line, self.sets);
        if self.filters[set] & filter_bit(tag) == 0 {
            return Probe::Miss; // fast reject: tag cannot be present
        }
        for (w, e) in self.row(set).iter().enumerate() {
            if e.valid && e.tag == tag {
                let missing = sectors & !e.sector_valid;
                return if missing == 0 {
                    Probe::Hit {
                        way: w as u32,
                        dirty: e.sector_dirty & sectors != 0,
                    }
                } else {
                    Probe::SectorMiss {
                        way: w as u32,
                        missing,
                    }
                };
            }
        }
        Probe::Miss
    }

    /// Probe and update LRU on line presence (hit or sector-miss).
    pub fn lookup(&mut self, line: LineAddr, sectors: SectorMask) -> Probe {
        let probe = self.peek(line, sectors);
        if let Probe::Hit { way, .. } | Probe::SectorMiss { way, .. } = probe {
            self.touch_way(decode::set_index(line, self.sets), way);
        }
        probe
    }

    fn touch_way(&mut self, set: usize, way: u32) {
        self.use_tick += 1;
        let t = self.use_tick;
        self.row_mut(set)[way as usize].last_use = t;
    }

    /// Mark sectors dirty (write hit). Returns false if the line is absent.
    pub fn mark_dirty(&mut self, line: LineAddr, sectors: SectorMask) -> bool {
        let set = decode::set_index(line, self.sets);
        let tag = decode::tag(line, self.sets);
        self.use_tick += 1;
        let t = self.use_tick;
        for e in self.row_mut(set) {
            if e.valid && e.tag == tag {
                e.sector_dirty |= sectors & e.sector_valid;
                e.last_use = t;
                return true;
            }
        }
        false
    }

    /// Is any requested sector of this line dirty? (remote-read dirty check,
    /// §III-C).
    pub fn is_dirty(&self, line: LineAddr, sectors: SectorMask) -> bool {
        matches!(self.peek(line, sectors), Probe::Hit { dirty: true, .. })
    }

    /// Install (or extend) a line with `sectors`.  If the line is absent
    /// and no way is free, the LRU line is evicted and reported — clean
    /// victims too (`dirty_sectors == 0`), so residency bookkeeping sees
    /// every departure; write-back paths must check
    /// [`Eviction::needs_writeback`].
    pub fn fill(&mut self, line: LineAddr, sectors: SectorMask) -> Option<Eviction> {
        let set = decode::set_index(line, self.sets);
        let tag = decode::tag(line, self.sets);
        self.use_tick += 1;
        let t = self.use_tick;
        let sets = self.sets;

        // Already present: just extend sector validity.
        for e in self.row_mut(set) {
            if e.valid && e.tag == tag {
                e.sector_valid |= sectors;
                e.last_use = t;
                return None;
            }
        }
        // Free way?
        if let Some(e) = self.row_mut(set).iter_mut().find(|e| !e.valid) {
            *e = TagEntry {
                valid: true,
                tag,
                sector_valid: sectors,
                sector_dirty: 0,
                last_use: t,
            };
            self.filters[set] |= filter_bit(tag);
            return None;
        }
        // Evict LRU.
        let victim_way = self
            .row(set)
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.last_use)
            .map(|(w, _)| w)
            .unwrap();
        let victim = self.row(set)[victim_way];
        let evicted = Some(Eviction {
            line: decode::line_from(victim.tag, set, sets),
            dirty_sectors: victim.sector_dirty,
        });
        self.row_mut(set)[victim_way] = TagEntry {
            valid: true,
            tag,
            sector_valid: sectors,
            sector_dirty: 0,
            last_use: t,
        };
        self.rebuild_filter(set);
        evicted
    }

    /// Recompute a set's presence filter (after eviction/invalidation).
    fn rebuild_filter(&mut self, set: usize) {
        let mut f = 0u64;
        for e in &self.entries[set * self.assoc..(set + 1) * self.assoc] {
            if e.valid {
                f |= filter_bit(e.tag);
            }
        }
        self.filters[set] = f;
    }

    /// Invalidate a line if present (used by tests and coherence probes).
    pub fn invalidate(&mut self, line: LineAddr) -> bool {
        let set = decode::set_index(line, self.sets);
        let tag = decode::tag(line, self.sets);
        for e in self.row_mut(set) {
            if e.valid && e.tag == tag {
                e.valid = false;
                self.rebuild_filter(set);
                return true;
            }
        }
        false
    }

    /// Count of valid lines (occupancy metric).
    pub fn occupancy(&self) -> usize {
        self.entries.iter().filter(|e| e.valid).count()
    }

    /// Iterate all resident line addresses (used by replication audits).
    pub fn resident_lines(&self) -> Vec<LineAddr> {
        let mut out = Vec::with_capacity(self.occupancy());
        for set in 0..self.sets {
            for e in self.row(set) {
                if e.valid {
                    out.push(decode::line_from(e.tag, set, self.sets));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ta(sets: usize, assoc: usize) -> TagArray {
        TagArray::new(sets, assoc)
    }

    #[test]
    fn miss_then_fill_then_hit() {
        let mut t = ta(8, 4);
        assert_eq!(t.peek(42, 0b1111), Probe::Miss);
        assert!(t.fill(42, 0b1111).is_none());
        assert!(matches!(t.peek(42, 0b1111), Probe::Hit { .. }));
        assert!(matches!(t.peek(42, 0b0001), Probe::Hit { .. }));
    }

    #[test]
    fn sector_miss_reports_missing_sectors() {
        let mut t = ta(8, 4);
        t.fill(42, 0b0011);
        match t.peek(42, 0b0111) {
            Probe::SectorMiss { missing, .. } => assert_eq!(missing, 0b0100),
            other => panic!("expected sector miss, got {other:?}"),
        }
        // Fill the missing sector; now full hit.
        t.fill(42, 0b0100);
        assert!(matches!(t.peek(42, 0b0111), Probe::Hit { .. }));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut t = ta(1, 2); // one set, two ways
        t.fill(0, 1);
        t.fill(1, 1);
        t.lookup(0, 1); // 0 is now MRU
        t.fill(2, 1); // must evict 1
        assert!(matches!(t.peek(0, 1), Probe::Hit { .. }));
        assert_eq!(t.peek(1, 1), Probe::Miss);
        assert!(matches!(t.peek(2, 1), Probe::Hit { .. }));
    }

    #[test]
    fn peek_does_not_disturb_lru() {
        let mut t = ta(1, 2);
        t.fill(0, 1);
        t.fill(1, 1);
        t.peek(0, 1); // must NOT promote 0
        t.fill(2, 1); // evicts 0 (oldest by use)
        assert_eq!(t.peek(0, 1), Probe::Miss);
        assert!(matches!(t.peek(1, 1), Probe::Hit { .. }));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut t = ta(1, 1);
        t.fill(10, 0b0011);
        assert!(t.mark_dirty(10, 0b0001));
        let ev = t.fill(11, 0b1111).expect("dirty victim");
        assert_eq!(ev.line, 10);
        assert_eq!(ev.dirty_sectors, 0b0001);
        assert!(ev.needs_writeback());
        // Clean victims are reported too (residency bookkeeping needs
        // every eviction) but carry no write-back data.
        let clean = t.fill(12, 0b1111).expect("clean victim still reported");
        assert_eq!(clean.line, 11);
        assert_eq!(clean.dirty_sectors, 0);
        assert!(!clean.needs_writeback());
    }

    #[test]
    fn fills_into_free_ways_or_present_lines_report_no_victim() {
        let mut t = ta(1, 2);
        assert!(t.fill(0, 0b0011).is_none(), "free way");
        assert!(t.fill(0, 0b1100).is_none(), "sector extension");
        assert!(t.fill(1, 0b1111).is_none(), "second free way");
    }

    #[test]
    fn dirty_flag_visible_to_remote_probe() {
        let mut t = ta(8, 2);
        t.fill(5, 0b1111);
        assert!(!t.is_dirty(5, 0b1111));
        t.mark_dirty(5, 0b0010);
        assert!(t.is_dirty(5, 0b0010));
        assert!(t.is_dirty(5, 0b1111));
        assert!(!t.is_dirty(5, 0b1101));
    }

    #[test]
    fn mark_dirty_only_on_valid_sectors() {
        let mut t = ta(8, 2);
        t.fill(5, 0b0001);
        t.mark_dirty(5, 0b1111);
        // Only the valid sector can be dirty.
        match t.peek(5, 0b0001) {
            Probe::Hit { dirty, .. } => assert!(dirty),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn distinct_sets_do_not_interfere() {
        let mut t = ta(8, 1);
        for line in 0..8u64 {
            t.fill(line, 1);
        }
        for line in 0..8u64 {
            assert!(matches!(t.peek(line, 1), Probe::Hit { .. }));
        }
        assert_eq!(t.occupancy(), 8);
    }

    #[test]
    fn same_set_lines_compete() {
        let mut t = ta(8, 2);
        // lines 0, 8, 16 all map to set 0
        t.fill(0, 1);
        t.fill(8, 1);
        t.fill(16, 1);
        assert_eq!(t.occupancy(), 2);
        assert_eq!(t.peek(0, 1), Probe::Miss, "LRU of set 0 evicted");
    }

    #[test]
    fn invalidate_removes_line() {
        let mut t = ta(8, 2);
        t.fill(3, 1);
        assert!(t.invalidate(3));
        assert_eq!(t.peek(3, 1), Probe::Miss);
        assert!(!t.invalidate(3));
    }

    #[test]
    fn resident_lines_roundtrip() {
        let mut t = ta(8, 4);
        let lines = [1u64, 9, 17, 100, 1000];
        for &l in &lines {
            t.fill(l, 0b1111);
        }
        let mut got = t.resident_lines();
        got.sort_unstable();
        let mut want = lines.to_vec();
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
