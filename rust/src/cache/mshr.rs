//! Miss Status Holding Registers.
//!
//! One entry per outstanding missed *line*; later misses to the same line
//! merge onto the entry (up to `max_merges`) instead of issuing duplicate
//! memory traffic.  When the fill returns, all merged requests complete
//! together.  A full MSHR (no entries, or a full merge list) back-pressures
//! the cache pipeline — one of the contention sources the paper's shared
//! caches suffer from.

use crate::mem::{LineAddr, MemRequest, SectorMask};
use crate::util::fxhash::FxHashMap;

#[derive(Debug, Clone)]
struct Entry {
    /// Union of sectors requested by all merged requests.
    sectors: SectorMask,
    /// Requests waiting on this line.
    waiters: Vec<MemRequest>,
    /// True once the miss has been dispatched to the next level.
    issued: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MshrOutcome {
    /// New entry allocated; caller must dispatch the miss downstream.
    Allocated,
    /// Merged onto an in-flight miss; no new downstream traffic.
    Merged,
    /// Structural stall: no entry/merge slot available.
    Full,
}

#[derive(Debug, Clone)]
pub struct Mshr {
    entries: FxHashMap<LineAddr, Entry>,
    max_entries: usize,
    max_merges: usize,
}

impl Mshr {
    pub fn new(max_entries: usize, max_merges: usize) -> Self {
        assert!(max_entries > 0 && max_merges > 0);
        Mshr {
            entries: FxHashMap::with_capacity_and_hasher(max_entries, Default::default()),
            max_entries,
            max_merges,
        }
    }

    pub fn outstanding(&self) -> usize {
        self.entries.len()
    }

    pub fn is_tracking(&self, line: LineAddr) -> bool {
        self.entries.contains_key(&line)
    }

    /// Try to record a missed request.
    pub fn allocate(&mut self, req: MemRequest) -> MshrOutcome {
        if let Some(e) = self.entries.get_mut(&req.line) {
            if e.waiters.len() >= self.max_merges {
                return MshrOutcome::Full;
            }
            e.sectors |= req.sectors;
            e.waiters.push(req);
            return MshrOutcome::Merged;
        }
        if self.entries.len() >= self.max_entries {
            return MshrOutcome::Full;
        }
        self.entries.insert(
            req.line,
            Entry {
                sectors: req.sectors,
                waiters: vec![req],
                issued: false,
            },
        );
        MshrOutcome::Allocated
    }

    /// Sectors to fetch for a line's pending miss (union over waiters).
    pub fn pending_sectors(&self, line: LineAddr) -> Option<SectorMask> {
        self.entries.get(&line).map(|e| e.sectors)
    }

    /// Mark the downstream fetch as issued (idempotent).
    pub fn mark_issued(&mut self, line: LineAddr) {
        if let Some(e) = self.entries.get_mut(&line) {
            e.issued = true;
        }
    }

    pub fn is_issued(&self, line: LineAddr) -> bool {
        self.entries.get(&line).map(|e| e.issued).unwrap_or(false)
    }

    /// Fill arrived: release and return all waiters.
    pub fn fill(&mut self, line: LineAddr) -> Vec<MemRequest> {
        self.entries.remove(&line).map(|e| e.waiters).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::AccessKind;

    fn req(id: u64, line: LineAddr, sectors: SectorMask) -> MemRequest {
        MemRequest {
            id,
            core: 0,
            warp: 0,
            inst: 0,
            line,
            sectors,
            kind: AccessKind::Load,
            issue_cycle: 0,
        }
    }

    #[test]
    fn allocate_then_merge_then_fill() {
        let mut m = Mshr::new(4, 4);
        assert_eq!(m.allocate(req(1, 10, 0b0001)), MshrOutcome::Allocated);
        assert_eq!(m.allocate(req(2, 10, 0b0010)), MshrOutcome::Merged);
        assert_eq!(m.pending_sectors(10), Some(0b0011));
        let done = m.fill(10);
        assert_eq!(done.len(), 2);
        assert_eq!(m.outstanding(), 0);
        assert!(m.fill(10).is_empty(), "second fill is empty");
    }

    #[test]
    fn entry_capacity_stalls() {
        let mut m = Mshr::new(2, 4);
        assert_eq!(m.allocate(req(1, 1, 1)), MshrOutcome::Allocated);
        assert_eq!(m.allocate(req(2, 2, 1)), MshrOutcome::Allocated);
        assert_eq!(m.allocate(req(3, 3, 1)), MshrOutcome::Full);
        // Merges still allowed when entries are full.
        assert_eq!(m.allocate(req(4, 1, 1)), MshrOutcome::Merged);
    }

    #[test]
    fn merge_capacity_stalls() {
        let mut m = Mshr::new(4, 2);
        assert_eq!(m.allocate(req(1, 7, 1)), MshrOutcome::Allocated);
        assert_eq!(m.allocate(req(2, 7, 1)), MshrOutcome::Merged);
        assert_eq!(m.allocate(req(3, 7, 1)), MshrOutcome::Full, "merge list full");
    }

    #[test]
    fn issued_flag_is_per_line() {
        let mut m = Mshr::new(4, 4);
        m.allocate(req(1, 5, 1));
        m.allocate(req(2, 6, 1));
        assert!(!m.is_issued(5));
        m.mark_issued(5);
        assert!(m.is_issued(5));
        assert!(!m.is_issued(6));
    }

    #[test]
    fn never_double_allocates_a_line() {
        let mut m = Mshr::new(8, 8);
        for i in 0..5 {
            m.allocate(req(i, 42, 1 << (i % 4)));
        }
        assert_eq!(m.outstanding(), 1, "one entry regardless of merges");
        assert_eq!(m.fill(42).len(), 5);
    }
}
