//! Experiment coordinator: sweeps architectures × applications across
//! worker threads, aggregates results, and produces the paper's tables
//! and figures — plus the co-scheduling sweep ([`cosched`]) that measures
//! inter-application interference under shared L1 organizations.
//!
//! All sweep surfaces route through the [`crate::exec`] execution layer:
//! a sweep declares a [`ScenarioGrid`], materializes
//! [`SimJob`](crate::exec::SimJob)s, and hands them to a [`JobRunner`] —
//! results come back in submission order, so output is byte-identical
//! for any thread count.

pub mod cosched;
pub mod landscape;

pub use cosched::{CoSchedResults, CoSchedSweep};

use crate::config::{GpuConfig, L1ArchKind};
use crate::exec::{JobError, JobOutput, JobRunner, ResumeCache, ScenarioGrid, SimJob};
use crate::stats::SimResult;
use crate::trace::{apps, AppModel, LocalityClass};
use crate::util::json::Json;
use crate::util::table::geomean;

/// A sweep specification: which architectures, which apps, at what scale.
///
/// The embedded `cfg` carries every host-strategy knob into each job —
/// `sharing.residency_index` and `engine.event_driven` included — which
/// is how the differential tests (`residency_differential.rs`,
/// `event_determinism.rs`) flip a flag on an otherwise identical sweep
/// and diff the output bytes.
#[derive(Debug, Clone)]
pub struct Sweep {
    pub cfg: GpuConfig,
    pub archs: Vec<L1ArchKind>,
    pub apps: Vec<AppModel>,
    /// Workload intensity multiplier (1.0 = paper scale).
    pub scale: f64,
    pub threads: usize,
}

impl Sweep {
    /// Fig-8 sweep: all four architectures × all ten applications on the
    /// paper configuration.
    pub fn paper(scale: f64) -> Self {
        Sweep {
            cfg: GpuConfig::paper(L1ArchKind::Private),
            archs: vec![
                L1ArchKind::Private,
                L1ArchKind::RemoteSharing,
                L1ArchKind::DecoupledSharing,
                L1ArchKind::Ata,
            ],
            apps: apps::all_apps(),
            scale,
            threads: JobRunner::available(),
        }
    }

    /// The three-architecture comparison most figures use (the paper
    /// normalizes to private and plots decoupled + ATA).
    pub fn fig8(scale: f64) -> Self {
        let mut s = Sweep::paper(scale);
        s.archs = vec![
            L1ArchKind::Private,
            L1ArchKind::DecoupledSharing,
            L1ArchKind::Ata,
        ];
        s
    }

    /// The declarative grid this sweep materializes (arch-major, then
    /// app — the submission order results come back in).
    pub fn grid(&self) -> ScenarioGrid {
        ScenarioGrid::new(self.cfg.clone(), self.archs.clone(), self.apps.clone(), self.scale)
    }

    /// Run every (arch, app) pair on the execution layer's worker pool.
    /// Results are in submission order — byte-identical for any
    /// `threads` value (no post-hoc sorting; the runner's ordering
    /// guarantee is the determinism mechanism).
    pub fn run(&self) -> SweepResults {
        self.run_isolated(None, None)
    }

    /// [`run`](Self::run) with the fault-isolation surface exposed: a
    /// resume cache short-circuits jobs already present in a manifest,
    /// and `observer` sees every freshly completed job (the manifest
    /// writer).  Failed jobs land in [`SweepResults::failures`] instead
    /// of aborting the sweep — see [`JobRunner::run_grid`].
    pub fn run_isolated(
        &self,
        resume: Option<&ResumeCache>,
        observer: Option<&(dyn Fn(&SimJob, &JobOutput) + Sync)>,
    ) -> SweepResults {
        self.run_jobs(&self.grid().jobs(), resume, observer)
    }

    /// [`run_isolated`](Self::run_isolated) over explicitly materialized
    /// jobs — the entry point for callers that patch jobs before running
    /// (the CLI's `--inject` fault arming, the poisoned-grid smoke).
    pub fn run_jobs(
        &self,
        jobs: &[SimJob],
        resume: Option<&ResumeCache>,
        observer: Option<&(dyn Fn(&SimJob, &JobOutput) + Sync)>,
    ) -> SweepResults {
        let outcome = JobRunner::new(self.threads).run_grid(jobs, resume, observer);
        let mut results = Vec::new();
        let mut failures = Vec::new();
        for output in outcome.outputs {
            match output {
                JobOutput::Failed(e) => failures.push(e),
                other => results.push(other.into_solo()),
            }
        }
        SweepResults {
            results,
            failures,
            degraded: outcome.degraded,
        }
    }
}

/// Aggregated sweep output with the lookups the figures need.
#[derive(Debug, Clone, Default)]
pub struct SweepResults {
    pub results: Vec<SimResult>,
    /// Jobs that could not complete (typed, with diagnostic snapshots).
    /// Deterministic: the same grid fails the same way at any
    /// `--threads`/`--shards`/`--mem-workers`.
    pub failures: Vec<JobError>,
    /// Jobs that recovered on the serial degradation retry (host-flake
    /// indicator; empty in deterministic runs — see
    /// [`crate::exec::GridOutcome`]).
    pub degraded: Vec<String>,
}

impl SweepResults {
    pub fn get(&self, arch: L1ArchKind, app: &str) -> Option<&SimResult> {
        self.results
            .iter()
            .find(|r| r.arch == arch.name() && r.app == app)
    }

    /// IPC normalized to the private baseline (Fig 8's y-axis).
    pub fn norm_ipc(&self, arch: L1ArchKind, app: &str) -> Option<f64> {
        let base = self.get(L1ArchKind::Private, app)?.ipc();
        let x = self.get(arch, app)?.ipc();
        (base > 0.0).then(|| x / base)
    }

    /// L1 access latency normalized to private (Fig 3 / Fig 10's y-axis).
    /// Uses the paper's §IV-C stage metric.
    pub fn norm_latency(&self, arch: L1ArchKind, app: &str) -> Option<f64> {
        let base = self.get(L1ArchKind::Private, app)?.l1_stage_mean_latency;
        let x = self.get(arch, app)?.l1_stage_mean_latency;
        (base > 0.0).then(|| x / base)
    }

    /// Full load latency (including L2/DRAM) normalized to private.
    pub fn norm_full_latency(&self, arch: L1ArchKind, app: &str) -> Option<f64> {
        let base = self.get(L1ArchKind::Private, app)?.l1_mean_load_latency;
        let x = self.get(arch, app)?.l1_mean_load_latency;
        (base > 0.0).then(|| x / base)
    }

    /// Geomean of normalized IPC over a locality class (the paper's
    /// "12.0% on average" style numbers).
    pub fn class_geomean_ipc(&self, arch: L1ArchKind, class: LocalityClass) -> f64 {
        let names: Vec<&str> = apps::all_apps()
            .into_iter()
            .filter(|a| a.class == class)
            .map(|a| a.name)
            .collect();
        let xs: Vec<f64> = names
            .iter()
            .filter_map(|n| self.norm_ipc(arch, n))
            .collect();
        geomean(&xs)
    }

    /// Any job failed?  (The CLI maps this to its "completed with
    /// failures" exit code.)
    pub fn has_failures(&self) -> bool {
        !self.failures.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "degraded",
                Json::arr(self.degraded.iter().map(|d| d.as_str().into()).collect()),
            ),
            (
                "failures",
                Json::arr(self.failures.iter().map(JobError::to_json).collect()),
            ),
            (
                "results",
                Json::arr(self.results.iter().map(SimResult::to_json).collect()),
            ),
        ])
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    fn tiny_sweep() -> Sweep {
        Sweep {
            cfg: GpuConfig::tiny(L1ArchKind::Private),
            archs: vec![L1ArchKind::Private, L1ArchKind::Ata],
            apps: vec![synth::locality_knob(0.8, 0.25), synth::pure_streaming().scaled(0.25)],
            scale: 1.0,
            threads: 2,
        }
    }

    #[test]
    fn sweep_runs_all_pairs_in_submission_order() {
        let r = tiny_sweep().run();
        assert_eq!(r.results.len(), 4);
        assert!(r.get(L1ArchKind::Ata, "synth[s=0.80]").is_some());
        assert!(r.get(L1ArchKind::Private, "synth[stream]").is_some());
        // Results come back in the grid's submission order (arch-major,
        // then app) — never reordered after the fact.
        let keys: Vec<(String, String)> = r
            .results
            .iter()
            .map(|x| (x.arch.clone(), x.app.clone()))
            .collect();
        let expect: Vec<(String, String)> = [
            ("private", "synth[s=0.80]"),
            ("private", "synth[stream]"),
            ("ata", "synth[s=0.80]"),
            ("ata", "synth[stream]"),
        ]
        .iter()
        .map(|(a, b)| (a.to_string(), b.to_string()))
        .collect();
        assert_eq!(keys, expect);
    }

    #[test]
    fn norm_ipc_is_one_for_private() {
        let r = tiny_sweep().run();
        let n = r.norm_ipc(L1ArchKind::Private, "synth[stream]").unwrap();
        assert!((n - 1.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_equals_serial() {
        let mut s = tiny_sweep();
        let a = s.run();
        s.threads = 1;
        let b = s.run();
        assert_eq!(a.results.len(), b.results.len());
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.cycles, y.cycles, "{}/{}", x.arch, x.app);
            assert_eq!(x.insts, y.insts);
        }
        // The strongest form of the contract: the serialized output is
        // byte-identical across thread counts.
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
    }
}
