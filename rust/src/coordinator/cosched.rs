//! Co-scheduling sweep: app-pair combinations × L1 organizations, with
//! per-app normalized IPC, slowdown vs. solo execution, and a CIAO-style
//! interference matrix.
//!
//! For every unordered app pair (i ≤ j) and architecture the sweep runs
//! one co-execution ([`crate::engine::Engine::run_multi`]) of the two
//! apps on the two halves of the GPU, plus one *solo* baseline per app
//! and partition position: the app alone on exactly the cores (and in
//! exactly the address space) it occupies in the co-run, with the rest of
//! the GPU idle.  Slowdown of app `x` co-run with `y` is then
//! `solo_ipc(x) / co_ipc(x)` — pure interference through the shared L1
//! organization, NoC, L2 and DRAM, with the capacity loss of
//! partitioning already factored out.

use crate::config::{GpuConfig, L1ArchKind};
use crate::core::CorePartition;
use crate::engine::MultiWorkload;
use crate::exec::{job_seed, JobError, JobOutput, JobRunner, ResumeCache, SimJob};
use crate::stats::{ContentionBreakdown, MultiResult, ResourceClass};
use crate::trace::{apps, co_workload_placed, AppModel};
use crate::util::json::Json;
use crate::util::table::Table;

/// A co-scheduling sweep specification.
#[derive(Debug, Clone)]
pub struct CoSchedSweep {
    pub cfg: GpuConfig,
    pub archs: Vec<L1ArchKind>,
    pub apps: Vec<AppModel>,
    /// Workload intensity multiplier (1.0 = paper scale).
    pub scale: f64,
    pub threads: usize,
    /// When true, lanes keep their generated addresses so co-run
    /// instances read-share data; default is disjoint address spaces.
    pub share_address_space: bool,
}

/// One co-run: apps `i` and `j` (registry indices, `i <= j`) under `arch`.
#[derive(Debug, Clone)]
pub struct PairResult {
    pub arch: L1ArchKind,
    pub i: usize,
    pub j: usize,
    pub result: MultiResult,
}

/// One solo baseline: app `app` alone on partition position `pos`.
#[derive(Debug, Clone)]
pub struct SoloResult {
    pub arch: L1ArchKind,
    pub app: usize,
    pub pos: usize,
    pub result: MultiResult,
}

impl CoSchedSweep {
    /// Default sweep: all ten paper apps, private baseline + ATA, paper
    /// GPU split in half.
    pub fn paper(scale: f64) -> Self {
        CoSchedSweep {
            cfg: GpuConfig::paper(L1ArchKind::Private),
            archs: vec![L1ArchKind::Private, L1ArchKind::Ata],
            apps: apps::all_apps(),
            scale,
            threads: JobRunner::available(),
            share_address_space: false,
        }
    }

    /// The two half-GPU partitions every pair runs on.
    pub fn partitions(&self) -> Vec<CorePartition> {
        CorePartition::even(self.cfg.cores, 2).expect("config has at least 2 cores")
    }

    /// Number of simulations the sweep will run: per architecture, one
    /// solo baseline per (app × position) plus every unordered pair.
    pub fn job_count(&self) -> usize {
        let n = self.apps.len();
        self.archs.len() * (n * self.partitions().len() + n * (n + 1) / 2)
    }

    /// Build a (solo or pair) co-workload with lanes at the given
    /// positions.  The address slot is the *position*, not the lane
    /// index, so solo baselines replay the exact co-run address stream.
    fn workload_at(
        &self,
        cfg: &GpuConfig,
        apps: &[&AppModel],
        parts: &[CorePartition],
        positions: &[usize],
    ) -> MultiWorkload {
        let scaled: Vec<AppModel> = apps.iter().map(|a| a.scaled(self.scale)).collect();
        co_workload_placed(cfg, &scaled, parts, positions, self.share_address_space)
            .expect("co-sched partitions are valid by construction")
    }

    /// Flatten the whole sweep — solo lanes *and* all pairs — into one
    /// [`SimJob`] list in deterministic submission order: per
    /// architecture, first every (app × position) solo baseline, then
    /// every unordered pair (i ≤ j).  The paired `slots` vector records
    /// how to route each output back into [`CoSchedResults`].
    fn jobs(&self) -> (Vec<SimJob>, Vec<CoSlot>) {
        let parts = self.partitions();
        let grid_seed = self.cfg.seed;
        let mut jobs: Vec<SimJob> = Vec::new();
        let mut slots: Vec<CoSlot> = Vec::new();
        for &arch in &self.archs {
            let mut cfg = self.cfg.clone();
            cfg.l1_arch = arch;
            for app in 0..self.apps.len() {
                for pos in 0..parts.len() {
                    let multi = self.workload_at(&cfg, &[&self.apps[app]], &[parts[pos]], &[pos]);
                    let label = format!("solo/{}/{}@p{pos}", arch.name(), self.apps[app].name);
                    jobs.push(SimJob::multi(
                        label,
                        cfg.clone(),
                        job_seed(grid_seed, jobs.len()),
                        multi,
                    ));
                    slots.push(CoSlot::Solo { arch, app, pos });
                }
            }
            for i in 0..self.apps.len() {
                for j in i..self.apps.len() {
                    let multi = self.workload_at(
                        &cfg,
                        &[&self.apps[i], &self.apps[j]],
                        &[parts[0], parts[1]],
                        &[0, 1],
                    );
                    let label = format!(
                        "pair/{}/{}+{}",
                        arch.name(),
                        self.apps[i].name,
                        self.apps[j].name
                    );
                    jobs.push(SimJob::multi(
                        label,
                        cfg.clone(),
                        job_seed(grid_seed, jobs.len()),
                        multi,
                    ));
                    slots.push(CoSlot::Pair { arch, i, j });
                }
            }
        }
        (jobs, slots)
    }

    /// Run all (arch × pair) co-runs and (arch × app × position) solo
    /// baselines on the execution layer's worker pool.  Outputs come
    /// back in submission order and are routed straight into the result
    /// vectors — no post-hoc sorting, so the serialized output is
    /// byte-identical for any `threads` value.
    pub fn run(&self) -> CoSchedResults {
        self.run_isolated(None, None)
    }

    /// [`run`](Self::run) with the fault-isolation surface exposed
    /// (resume cache + manifest observer — see
    /// [`JobRunner::run_grid`]).  A failed job leaves a hole in the
    /// lookup tables (its `norm_ipc`/`slowdown` read as `None`) and a
    /// typed record in [`CoSchedResults::failures`]; the rest of the
    /// sweep completes.
    pub fn run_isolated(
        &self,
        resume: Option<&ResumeCache>,
        observer: Option<&(dyn Fn(&SimJob, &JobOutput) + Sync)>,
    ) -> CoSchedResults {
        let (jobs, slots) = self.jobs();
        let outcome = JobRunner::new(self.threads).run_grid(&jobs, resume, observer);
        let mut pairs = Vec::new();
        let mut solos = Vec::new();
        let mut failures = Vec::new();
        for (slot, output) in slots.into_iter().zip(outcome.outputs) {
            if let JobOutput::Failed(e) = output {
                failures.push(e);
                continue;
            }
            let result = output.into_multi();
            match slot {
                CoSlot::Solo { arch, app, pos } => {
                    solos.push(SoloResult { arch, app, pos, result })
                }
                CoSlot::Pair { arch, i, j } => pairs.push(PairResult { arch, i, j, result }),
            }
        }
        CoSchedResults {
            app_names: self.apps.iter().map(|a| a.name.to_string()).collect(),
            pairs,
            solos,
            failures,
            degraded: outcome.degraded,
        }
    }
}

/// Where one flattened co-scheduling job's output lands.
#[derive(Clone, Copy)]
enum CoSlot {
    Solo { arch: L1ArchKind, app: usize, pos: usize },
    Pair { arch: L1ArchKind, i: usize, j: usize },
}

/// Aggregated co-scheduling output with the interference lookups.
#[derive(Debug, Clone, Default)]
pub struct CoSchedResults {
    pub app_names: Vec<String>,
    pub pairs: Vec<PairResult>,
    pub solos: Vec<SoloResult>,
    /// Jobs that could not complete (typed, with diagnostic snapshots).
    pub failures: Vec<JobError>,
    /// Jobs that recovered on the serial degradation retry (host-flake
    /// indicator; empty in deterministic runs).
    pub degraded: Vec<String>,
}

impl CoSchedResults {
    /// Solo baseline of app `app` (registry index) at position `pos`.
    pub fn solo(&self, arch: L1ArchKind, app: usize, pos: usize) -> Option<&MultiResult> {
        self.solos
            .iter()
            .find(|r| r.arch == arch && r.app == app && r.pos == pos)
            .map(|r| &r.result)
    }

    /// Co-run of apps `i` and `j` (order-insensitive).
    pub fn pair(&self, arch: L1ArchKind, i: usize, j: usize) -> Option<&PairResult> {
        let (a, b) = if i <= j { (i, j) } else { (j, i) };
        self.pairs
            .iter()
            .find(|p| p.arch == arch && p.i == a && p.j == b)
    }

    /// IPC of app `x` inside the co-run with `other`.
    pub fn co_ipc(&self, arch: L1ArchKind, x: usize, other: usize) -> Option<f64> {
        let p = self.pair(arch, x, other)?;
        // Lane 0 holds the smaller index (or `x` itself for self-pairs).
        let lane = if x <= other { 0 } else { 1 };
        Some(p.result.apps[lane].ipc())
    }

    /// IPC of app `x` running alone on the cores it occupies in the
    /// co-run with `other`.
    pub fn solo_ipc(&self, arch: L1ArchKind, x: usize, other: usize) -> Option<f64> {
        let pos = if x <= other { 0 } else { 1 };
        Some(self.solo(arch, x, pos)?.apps[0].ipc())
    }

    /// Normalized IPC of app `x` co-run with `other` (1.0 = no
    /// interference; this is Fig-8-style normalization, but against the
    /// partitioned solo baseline instead of a different architecture).
    pub fn norm_ipc(&self, arch: L1ArchKind, x: usize, other: usize) -> Option<f64> {
        let solo = self.solo_ipc(arch, x, other)?;
        let co = self.co_ipc(arch, x, other)?;
        (solo > 0.0).then(|| co / solo)
    }

    /// Slowdown of app `x` when co-run with `other` (CIAO's metric;
    /// ≥ 1.0 means interference hurt).
    pub fn slowdown(&self, arch: L1ArchKind, x: usize, other: usize) -> Option<f64> {
        let co = self.co_ipc(arch, x, other)?;
        let solo = self.solo_ipc(arch, x, other)?;
        (co > 0.0).then(|| solo / co)
    }

    /// Per-resource stall cycles app `x` *gains* when co-run with `other`
    /// relative to running alone on the same cores and address space —
    /// i.e. which shared resource the co-runner steals from it.  Classes
    /// where the co-run queued less (scheduling jitter) clamp to zero.
    pub fn stolen_breakdown(
        &self,
        arch: L1ArchKind,
        x: usize,
        other: usize,
    ) -> Option<ContentionBreakdown> {
        let p = self.pair(arch, x, other)?;
        // Lane index in the co-run == partition position of the solo
        // baseline (lane 0 holds the smaller registry index).
        let pos = if x <= other { 0 } else { 1 };
        let co = &p.result.apps[pos].contention;
        let solo = &self.solo(arch, x, pos)?.apps[0].contention;
        let mut out = ContentionBreakdown::default();
        for class in ResourceClass::ALL {
            out.add(class, co.get(class).saturating_sub(solo.get(class)));
        }
        Some(out)
    }

    /// Full interference matrix: `m[x][y]` = slowdown of app `x` when
    /// co-run with app `y`.
    pub fn interference_matrix(&self, arch: L1ArchKind) -> Vec<Vec<f64>> {
        let n = self.app_names.len();
        (0..n)
            .map(|x| {
                (0..n)
                    .map(|y| self.slowdown(arch, x, y).unwrap_or(0.0))
                    .collect()
            })
            .collect()
    }

    /// Render the interference matrix as a table (rows = victim app,
    /// columns = co-runner).
    pub fn render_matrix(&self, arch: L1ArchKind) -> String {
        self.render_matrix_from(arch, &self.interference_matrix(arch))
    }

    /// [`render_matrix`](Self::render_matrix) with a precomputed matrix,
    /// for callers that also need the raw values.
    pub fn render_matrix_from(&self, arch: L1ArchKind, m: &[Vec<f64>]) -> String {
        let mut header: Vec<&str> = vec!["slowdown of ↓ with →"];
        header.extend(self.app_names.iter().map(String::as_str));
        let mut t = Table::new(&format!("interference matrix — {}", arch.name()))
            .header(&header);
        for (x, row) in m.iter().enumerate() {
            let mut cells = vec![self.app_names[x].clone()];
            cells.extend(row.iter().map(|v| format!("{v:.3}")));
            t.row(cells);
        }
        t.render()
    }

    /// Any job failed?  (The CLI maps this to its "completed with
    /// failures" exit code.)
    pub fn has_failures(&self) -> bool {
        !self.failures.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "apps",
                Json::arr(self.app_names.iter().map(|n| n.as_str().into()).collect()),
            ),
            (
                "degraded",
                Json::arr(self.degraded.iter().map(|d| d.as_str().into()).collect()),
            ),
            (
                "failures",
                Json::arr(self.failures.iter().map(JobError::to_json).collect()),
            ),
            (
                "solos",
                Json::arr(
                    self.solos
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("arch", r.arch.name().into()),
                                ("app", r.app.into()),
                                ("pos", r.pos.into()),
                                ("result", r.result.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "pairs",
                Json::arr(
                    self.pairs
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("arch", p.arch.name().into()),
                                ("i", p.i.into()),
                                ("j", p.j.into()),
                                ("result", p.result.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth;

    fn tiny_sweep() -> CoSchedSweep {
        CoSchedSweep {
            cfg: GpuConfig::tiny(L1ArchKind::Private),
            archs: vec![L1ArchKind::Private, L1ArchKind::Ata],
            apps: vec![synth::locality_knob(0.8, 0.25), synth::pure_streaming().scaled(0.25)],
            scale: 1.0,
            threads: 2,
            share_address_space: false,
        }
    }

    #[test]
    fn sweep_runs_all_pairs_and_solos() {
        let r = tiny_sweep().run();
        // 2 archs × (3 unordered pairs + 2 apps × 2 positions).
        assert_eq!(r.pairs.len(), 6);
        assert_eq!(r.solos.len(), 8);
        for arch in [L1ArchKind::Private, L1ArchKind::Ata] {
            for x in 0..2 {
                for y in 0..2 {
                    let s = r.slowdown(arch, x, y).unwrap();
                    assert!(s > 0.0, "{} {x} vs {y}: {s}", arch.name());
                    let n = r.norm_ipc(arch, x, y).unwrap();
                    assert!((0.01..=100.0).contains(&n));
                }
            }
        }
        let m = r.interference_matrix(L1ArchKind::Ata);
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].len(), 2);
        assert!(r.render_matrix(L1ArchKind::Ata).contains("interference"));
        // The stolen-resource lookup is populated for every pairing and
        // never reports a class the co-run did not actually queue on.
        for x in 0..2 {
            for y in 0..2 {
                let stolen = r.stolen_breakdown(L1ArchKind::Ata, x, y).unwrap();
                let co = r.pair(L1ArchKind::Ata, x, y).unwrap();
                let lane = if x <= y { 0 } else { 1 };
                assert!(stolen.total() <= co.result.apps[lane].contention.total());
            }
        }
    }

    #[test]
    fn cosched_parallel_equals_serial() {
        let mut s = tiny_sweep();
        let a = s.run();
        s.threads = 1;
        let b = s.run();
        assert_eq!(a.pairs.len(), b.pairs.len());
        for (x, y) in a.pairs.iter().zip(&b.pairs) {
            assert_eq!(x.result.cycles, y.result.cycles, "{}/{}", x.i, x.j);
            assert_eq!(x.result.insts, y.result.insts);
        }
        for (x, y) in a.solos.iter().zip(&b.solos) {
            assert_eq!(x.result.cycles, y.result.cycles);
        }
        // Byte-identical serialized output across thread counts.
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
    }

    #[test]
    fn json_roundtrip_is_parseable() {
        let r = tiny_sweep().run();
        let j = r.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("apps").unwrap().as_arr().unwrap().len(), 2);
    }
}
