//! ATA-Cache: contention mitigation for GPU shared L1 caches with an
//! aggregated tag array — a full-system reproduction.
//!
//! The crate contains:
//!
//! * a cycle-level GPU memory-system simulator — SIMT cores with GTO
//!   schedulers ([`core`](crate::core)), sectored caches ([`cache`]),
//!   crossbar/ring interconnects with iSLIP arbitration ([`noc`]),
//!   banked L2 + DRAM bank timing ([`l2`], [`dram`]) — configured per
//!   the paper's Table II ([`config`]);
//! * the paper's four L1 organizations plus an interference-aware
//!   bypass variant, expressed as [`l1arch::SharingPolicy`] modules over
//!   one shared transaction pipeline ([`l1arch::pipeline`]) and
//!   registered in [`l1arch::REGISTRY`]; every request travels as a
//!   first-class [`mem::MemTxn`] with per-hop timestamps;
//! * statistical workload models of the ten benchmark applications
//!   ([`trace`]), plus extra models for co-execution studies;
//! * single-app and multi-app execution engines ([`engine`]): N
//!   applications can co-execute on disjoint core partitions while
//!   sharing the L1 organization, NoC, L2 and DRAM, making
//!   inter-application interference measurable;
//! * a deterministic parallel experiment-execution layer ([`exec`]):
//!   every sweep surface materializes self-contained [`exec::SimJob`]s
//!   and runs them on a work-stealing [`exec::JobRunner`] whose results
//!   come back in submission order — output is byte-identical for any
//!   `--threads` value;
//! * the experiment coordinator regenerating every table and figure
//!   ([`coordinator`]), the co-scheduling interference sweep
//!   ([`coordinator::cosched`]), and hardware-overhead modeling
//!   ([`area`]);
//! * the locality-analytics pipeline classifying workloads by
//!   inter-core data replication ([`runtime`]).

pub mod analysis;
pub mod area;
pub mod bench_harness;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod dram;
pub mod engine;
pub mod exec;
pub mod l1arch;
pub mod l2;
pub mod mem;
pub mod noc;
pub mod resource;
pub mod runtime;
pub mod stats;
pub mod testkit;
pub mod trace;
pub mod util;
