//! Property-testing harness (the offline crate set has no `proptest`).
//!
//! A small combinator library: generators draw values from a [`Pcg32`]
//! stream; [`check`] runs a property over many random cases and, on
//! failure, retries with simpler draws (halved sizes) to report a small
//! counterexample — shrinking-lite.  Used by the `property_*` tests across
//! the simulator modules.
//!
//! Also hosts shared scenario builders — deterministic config/workload
//! pairs engineered to hit a specific regime (see
//! [`stall_heavy_scenario`]) — so integration tests across files exercise
//! the same pathological shapes instead of each inventing a weaker one.

use crate::config::{FaultKind, GpuConfig, L1ArchKind};
use crate::core::{WarpInst, WarpProgram};
use crate::engine::{KernelSpec, Workload};
use crate::util::rng::Pcg32;

/// A deterministic stall-heavy scenario: a [`GpuConfig::tiny`] variant
/// whose DRAM back end is throttled to one controller with a near-empty
/// queue, paired with a miss-storm workload in which every load touches a
/// brand-new line (100% cold misses, no reuse, no sharing).  Misses pile
/// up behind the single controller, so cores spend long stretches with
/// nothing to issue — exactly the regime the event-driven clock exists
/// for.  Used by the `cycles_simulated > cycles_ticked` telemetry
/// regression below and available to integration tests that need a
/// backlog-bound workload.
pub fn stall_heavy_scenario(arch: L1ArchKind) -> (GpuConfig, Workload) {
    let mut cfg = GpuConfig::tiny(arch);
    cfg.dram.controllers = 1;
    cfg.dram.queue_depth = 2;
    let warps = 4;
    let loads_per_warp = 24;
    let mut next_line = 0u64;
    let programs = (0..cfg.cores)
        .map(|_| {
            (0..warps)
                .map(|_| {
                    let insts = (0..loads_per_warp)
                        .map(|_| {
                            let line = next_line;
                            next_line += 1;
                            WarpInst::Load(vec![(line, 0b1111)])
                        })
                        .collect();
                    WarpProgram::new(insts)
                })
                .collect()
        })
        .collect();
    let wl = Workload {
        name: "stall-heavy".into(),
        kernels: vec![KernelSpec { name: "miss-storm".into(), programs }],
    };
    (cfg, wl)
}

/// A stall-heavy scenario long enough to cross the engine's periodic
/// stale-entry sweep boundary ([`crate::engine::SWEEP_PERIOD`] cycles),
/// with the L2-visible reuse pattern that makes sweep *timing*
/// metric-visible: every warp streams through a private block of unique
/// lines (first pass: cold misses that leave L2 in-flight entries
/// behind), then re-reads the whole block (second pass: the lines have
/// long been evicted from the thrashed L2, so each re-read lands in
/// [`crate::l2::MemSystem::fetch`]'s in-flight merge window — a stale
/// entry is a cheap merge-hit, an absent one a full DRAM trip).  A
/// sweep that fires at clock-cadence-dependent cycles partitions those
/// re-reads differently between the two clock modes; the differential
/// referee in `event_determinism.rs` runs this scenario in both modes
/// and asserts the run really crossed a boundary.
pub fn sweep_crossing_scenario(arch: L1ArchKind) -> (GpuConfig, Workload) {
    let mut cfg = GpuConfig::tiny(arch);
    cfg.dram.controllers = 1;
    cfg.dram.queue_depth = 2;
    let warps = 4;
    // 8 cores x 4 warps x 384 unique lines = 12_288 cold misses, each
    // re-read once (24_576 DRAM-bound accesses).  Serialized on the
    // single throttled controller this runs well past SWEEP_PERIOD
    // (asserted by the consuming test, not assumed here).
    let lines_per_warp = 384u64;
    let mut next_block = 0u64;
    let programs = (0..cfg.cores)
        .map(|_| {
            (0..warps)
                .map(|_| {
                    let base = next_block * lines_per_warp;
                    next_block += 1;
                    let block = base..base + lines_per_warp;
                    let insts = block
                        .clone()
                        .chain(block)
                        .map(|line| WarpInst::Load(vec![(line, 0b1111)]))
                        .collect();
                    WarpProgram::new(insts)
                })
                .collect()
        })
        .collect();
    let wl = Workload {
        name: "sweep-crossing".into(),
        kernels: vec![KernelSpec { name: "reuse-storm".into(), programs }],
    };
    (cfg, wl)
}

/// A deterministic sharing-plus-streaming scenario for the sharded
/// engine's differential referee (`rust/tests/shard_determinism.rs`):
/// every warp interleaves loads to a block of lines shared by its whole
/// cluster with a stream of brand-new lines that miss to a throttled
/// DRAM back end.  The shared block produces remote/ATA hits — which,
/// because sharding is cluster-aligned, never cross a shard boundary by
/// construction — while the cold misses are the real cross-shard
/// traffic: every shard's transactions funnel through the shared
/// L2/DRAM walk (egress) and their long-latency fills come back as
/// per-shard ingress wakes, often epochs later.  Those two flows are
/// exactly what [`crate::stats::ShardStats`] counts and the consuming
/// test asserts on.
pub fn cross_shard_scenario(arch: L1ArchKind) -> (GpuConfig, Workload) {
    let mut cfg = GpuConfig::tiny(arch);
    cfg.dram.controllers = 1;
    cfg.dram.queue_depth = 4;
    let warps = 4usize;
    let shared_lines = 16u64;
    let loads_per_warp = 32u64;
    let cpc = cfg.cores_per_cluster();
    let mut next_stream = 1u64 << 20;
    let programs = (0..cfg.cores)
        .map(|c| {
            let cluster = (c / cpc) as u64;
            (0..warps)
                .map(|w| {
                    let mut insts = Vec::new();
                    for i in 0..loads_per_warp {
                        // Rotate the cluster-shared block per warp so
                        // accesses spread across banks but still
                        // collide across cluster-mates.
                        let shared = cluster * shared_lines + ((i + w as u64) % shared_lines);
                        insts.push(WarpInst::Load(vec![(shared, 0b1111)]));
                        let line = next_stream;
                        next_stream += 1;
                        insts.push(WarpInst::Load(vec![(line, 0b1111)]));
                    }
                    WarpProgram::new(insts)
                })
                .collect()
        })
        .collect();
    let wl = Workload {
        name: "cross-shard".into(),
        kernels: vec![KernelSpec { name: "share+stream".into(), programs }],
    };
    (cfg, wl)
}

/// A deterministic slice-skew scenario for the slice-parallel memory
/// walk's differential referee (`rust/tests/memwalk_determinism.rs`):
/// every load in the workload is chosen (by sieving the hashed slice
/// decode) to land on L2 slice 0, and every block is streamed twice.
/// With `engine.mem_workers > 1` this is the worst partition the walk
/// pool can face — one worker owns the hammered slice and every fetch
/// descriptor while its siblings idle — and the second pass piles
/// same-epoch re-reads on top (L2 in-flight merges and L1 deferred
/// merges against fetches resolved earlier in the same canonical
/// order).  If descriptor scatter, canonical-order merge, or the DRAM
/// sub-phase ever depended on which worker walked a slice, this shape
/// breaks first.  The consuming test asserts byte-identity against the
/// serial walk; the self-test below pins the skew property itself.
pub fn slice_skew_scenario(arch: L1ArchKind) -> (GpuConfig, Workload) {
    let mut cfg = GpuConfig::tiny(arch);
    cfg.dram.controllers = 1;
    cfg.dram.queue_depth = 4;
    let slices = cfg.l2.slices;
    let warps = 4usize;
    let lines_per_warp = 24usize;
    // Sieve the line space for addresses hashing to slice 0; each warp
    // takes the next run of them, so no two warps share a line but all
    // funnel into the same slice's tag array, port, and walk worker.
    let mut skewed = (0u64..).filter(|&l| crate::mem::decode::l2_slice(l, slices) == 0);
    let programs = (0..cfg.cores)
        .map(|_| {
            (0..warps)
                .map(|_| {
                    let block: Vec<u64> = skewed.by_ref().take(lines_per_warp).collect();
                    let insts = block
                        .iter()
                        .chain(block.iter())
                        .map(|&line| WarpInst::Load(vec![(line, 0b1111)]))
                        .collect();
                    WarpProgram::new(insts)
                })
                .collect()
        })
        .collect();
    let wl = Workload {
        name: "slice-skew".into(),
        kernels: vec![KernelSpec { name: "one-slice-storm".into(), programs }],
    };
    (cfg, wl)
}

/// The small all-miss load workload the fault scenarios share: one warp
/// per core, a handful of cold-miss loads each, every line unique.  Small
/// enough that the healthy portion drains in a few hundred cycles, so a
/// failure detector dominates the run instead of the workload.
fn fault_bait_workload(cfg: &GpuConfig, name: &str) -> Workload {
    let mut next_line = 0u64;
    let programs = (0..cfg.cores)
        .map(|_| {
            let insts = (0..4)
                .map(|_| {
                    let line = next_line;
                    next_line += 1;
                    WarpInst::Load(vec![(line, 0b1111)])
                })
                .collect();
            vec![WarpProgram::new(insts)]
        })
        .collect();
    Workload {
        name: name.into(),
        kernels: vec![KernelSpec { name: "bait".into(), programs }],
    }
}

/// A scenario engineered to end in `SimError::Deadlock`: the config arms
/// [`FaultKind::Deadlock`] — the engine swallows the first
/// load-completion wake, so one warp blocks forever while the rest of
/// the tiny all-miss workload drains — and the blocked-machine check
/// then fires with a diagnostic snapshot.  Shared by
/// `failure_determinism.rs` and the unit tests below so every consumer
/// observes the *same* failure bytes.
pub fn deadlock_scenario(arch: L1ArchKind) -> (GpuConfig, Workload) {
    let mut cfg = GpuConfig::tiny(arch);
    cfg.engine.fault = FaultKind::Deadlock;
    let wl = fault_bait_workload(&cfg, "deadlock-bait");
    (cfg, wl)
}

/// The livelock twin of [`deadlock_scenario`]: [`FaultKind::Livelock`]
/// bounces every due wake forward instead of delivering it, so the clock
/// advances forever while nothing retires — until the forward-progress
/// watchdog aborts the run as `SimError::Livelock` (with the same
/// snapshot shape the deadlock path reports).
pub fn livelock_scenario(arch: L1ArchKind) -> (GpuConfig, Workload) {
    let mut cfg = GpuConfig::tiny(arch);
    cfg.engine.fault = FaultKind::Livelock;
    let wl = fault_bait_workload(&cfg, "livelock-bait");
    (cfg, wl)
}

/// A reusable random-value generator.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Pcg32) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut Pcg32) -> T + 'static) -> Self {
        Gen { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Pcg32) -> T {
        (self.f)(rng)
    }

    /// Map the generated value.
    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng| g(self.sample(rng)))
    }
}

/// Uniform integer in `[lo, hi]`.
pub fn int_range(lo: u64, hi: u64) -> Gen<u64> {
    assert!(lo <= hi);
    Gen::new(move |rng| lo + (rng.next_u64() % (hi - lo + 1)))
}

/// Uniform f64 in `[0, 1)`.
pub fn unit_f64() -> Gen<f64> {
    Gen::new(|rng| rng.next_f64())
}

/// A vector of `len` draws from `item`.
pub fn vec_of<T: 'static>(item: Gen<T>, len: Gen<u64>) -> Gen<Vec<T>> {
    Gen::new(move |rng| {
        let n = len.sample(rng) as usize;
        (0..n).map(|_| item.sample(rng)).collect()
    })
}

/// One of the provided values, uniformly.
pub fn one_of<T: Clone + 'static>(choices: Vec<T>) -> Gen<T> {
    assert!(!choices.is_empty());
    Gen::new(move |rng| choices[rng.next_below(choices.len() as u32) as usize].clone())
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult {
    Ok,
    Failed {
        seed: u64,
        case: usize,
        message: String,
    },
}

/// Run `prop` over `cases` random inputs drawn from `gen`.
/// Panics with the seed + case index on failure (reproducible: the case
/// derives deterministically from the seed).
pub fn check<T: std::fmt::Debug + 'static>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Pcg32::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15), 7);
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (seed={seed} case={case}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_range_bounds() {
        let g = int_range(5, 10);
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..1000 {
            let x = g.sample(&mut rng);
            assert!((5..=10).contains(&x));
        }
    }

    #[test]
    fn vec_of_respects_len() {
        let g = vec_of(int_range(0, 9), int_range(3, 3));
        let mut rng = Pcg32::new(2, 1);
        assert_eq!(g.sample(&mut rng).len(), 3);
    }

    #[test]
    fn map_composes() {
        let g = int_range(1, 4).map(|x| x * 100);
        let mut rng = Pcg32::new(3, 1);
        for _ in 0..100 {
            let x = g.sample(&mut rng);
            assert!(x % 100 == 0 && (100..=400).contains(&x));
        }
    }

    #[test]
    fn check_passes_valid_property() {
        check("sum-commutes", 42, 200, &vec_of(int_range(0, 100), int_range(0, 10)), |xs| {
            let fwd: u64 = xs.iter().sum();
            let rev: u64 = xs.iter().rev().sum();
            (fwd == rev).then_some(()).ok_or_else(|| "sum not commutative?!".into())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn check_reports_failures() {
        check("always-fails", 1, 10, &int_range(0, 10), |_| Err("nope".into()));
    }

    /// The stall-heavy scenario must actually starve the cores: the
    /// event clock skips cycles (`cycles_simulated > cycles_ticked`),
    /// the cycle-by-cycle reference agrees byte-for-byte, and the
    /// telemetry stays out of the result JSON (the same exclusion
    /// contract as `crate::stats::ResidencyStats`).
    #[test]
    fn stall_heavy_scenario_exercises_the_event_clock() {
        use crate::engine::Engine;

        let (cfg, wl) = stall_heavy_scenario(L1ArchKind::Ata);
        let mut eng = Engine::new(&cfg);
        let r = eng.run(&wl).unwrap();
        let ev = eng.event_stats();
        assert!(r.loads > 0, "miss storm issued no loads");
        assert!(
            ev.cycles_simulated > ev.cycles_ticked,
            "stall-heavy scenario produced no skippable cycles: {ev:?}"
        );
        assert!(ev.jumps > 0 && ev.max_jump > 1, "clock never jumped: {ev:?}");
        // On a fresh engine the simulated-cycle count telescopes to the
        // reported cycle total.
        assert_eq!(ev.cycles_simulated, r.cycles);
        let js = r.to_json().to_string();
        assert!(
            !js.contains("cycles_ticked") && !js.contains("max_jump"),
            "event telemetry leaked into result JSON"
        );

        // Reference clock: same scenario, same bytes, nothing skipped.
        let mut cfg_off = cfg.clone();
        cfg_off.engine.event_driven = false;
        let mut eng_off = Engine::new(&cfg_off);
        let r_off = eng_off.run(&wl).unwrap();
        assert_eq!(r.to_json().pretty(), r_off.to_json().pretty());
        assert_eq!(eng_off.event_stats().skipped(), 0);
    }

    /// The fault scenarios must produce exactly their advertised typed
    /// errors, with a populated diagnostic snapshot — the contract
    /// `failure_determinism.rs` and the poisoned-grid smoke build on.
    #[test]
    fn fault_scenarios_produce_their_typed_errors() {
        use crate::engine::{Engine, SimError};

        let (cfg, wl) = deadlock_scenario(L1ArchKind::Ata);
        match Engine::new(&cfg).run(&wl) {
            Err(SimError::Deadlock(snap)) => {
                assert!(snap.cores_blocked > 0, "deadlock with no blocked core: {snap:?}");
                assert_eq!(snap.cores_total, cfg.cores as u64);
            }
            other => panic!("expected Deadlock, got {other:?}"),
        }

        let (cfg, wl) = livelock_scenario(L1ArchKind::Ata);
        match Engine::new(&cfg).run(&wl) {
            Err(SimError::Livelock { snap, .. }) => {
                assert!(snap.cycle > 0, "livelock tripped before the clock moved: {snap:?}");
            }
            other => panic!("expected Livelock, got {other:?}"),
        }
    }

    /// The skew property the memory-walk referee relies on: every load
    /// in the scenario really decodes to L2 slice 0, no two warps share
    /// a line (the second pass re-reads are intra-warp only), and the
    /// workload is non-trivial.
    #[test]
    fn slice_skew_scenario_hammers_exactly_one_slice() {
        let (cfg, wl) = slice_skew_scenario(L1ArchKind::Ata);
        let mut lines = Vec::new();
        for kernel in &wl.kernels {
            for programs in &kernel.programs {
                for prog in programs {
                    let mut own = std::collections::BTreeSet::new();
                    for inst in prog.insts() {
                        if let WarpInst::Load(reqs) = inst {
                            for &(line, _) in reqs {
                                assert_eq!(
                                    crate::mem::decode::l2_slice(line, cfg.l2.slices),
                                    0,
                                    "line {line} escaped the hammered slice"
                                );
                                own.insert(line);
                            }
                        }
                    }
                    lines.push(own);
                }
            }
        }
        let total: usize = lines.iter().map(|s| s.len()).sum();
        let distinct: std::collections::BTreeSet<u64> =
            lines.iter().flatten().copied().collect();
        assert_eq!(distinct.len(), total, "warps must not share lines");
        assert!(total >= 256, "scenario too small to stress the walk: {total}");
    }
}
