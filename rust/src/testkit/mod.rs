//! Property-testing harness (the offline crate set has no `proptest`).
//!
//! A small combinator library: generators draw values from a [`Pcg32`]
//! stream; [`check`] runs a property over many random cases and, on
//! failure, retries with simpler draws (halved sizes) to report a small
//! counterexample — shrinking-lite.  Used by the `property_*` tests across
//! the simulator modules.

use crate::util::rng::Pcg32;

/// A reusable random-value generator.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Pcg32) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut Pcg32) -> T + 'static) -> Self {
        Gen { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Pcg32) -> T {
        (self.f)(rng)
    }

    /// Map the generated value.
    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng| g(self.sample(rng)))
    }
}

/// Uniform integer in `[lo, hi]`.
pub fn int_range(lo: u64, hi: u64) -> Gen<u64> {
    assert!(lo <= hi);
    Gen::new(move |rng| lo + (rng.next_u64() % (hi - lo + 1)))
}

/// Uniform f64 in `[0, 1)`.
pub fn unit_f64() -> Gen<f64> {
    Gen::new(|rng| rng.next_f64())
}

/// A vector of `len` draws from `item`.
pub fn vec_of<T: 'static>(item: Gen<T>, len: Gen<u64>) -> Gen<Vec<T>> {
    Gen::new(move |rng| {
        let n = len.sample(rng) as usize;
        (0..n).map(|_| item.sample(rng)).collect()
    })
}

/// One of the provided values, uniformly.
pub fn one_of<T: Clone + 'static>(choices: Vec<T>) -> Gen<T> {
    assert!(!choices.is_empty());
    Gen::new(move |rng| choices[rng.next_below(choices.len() as u32) as usize].clone())
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult {
    Ok,
    Failed {
        seed: u64,
        case: usize,
        message: String,
    },
}

/// Run `prop` over `cases` random inputs drawn from `gen`.
/// Panics with the seed + case index on failure (reproducible: the case
/// derives deterministically from the seed).
pub fn check<T: std::fmt::Debug + 'static>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Pcg32::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15), 7);
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (seed={seed} case={case}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_range_bounds() {
        let g = int_range(5, 10);
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..1000 {
            let x = g.sample(&mut rng);
            assert!((5..=10).contains(&x));
        }
    }

    #[test]
    fn vec_of_respects_len() {
        let g = vec_of(int_range(0, 9), int_range(3, 3));
        let mut rng = Pcg32::new(2, 1);
        assert_eq!(g.sample(&mut rng).len(), 3);
    }

    #[test]
    fn map_composes() {
        let g = int_range(1, 4).map(|x| x * 100);
        let mut rng = Pcg32::new(3, 1);
        for _ in 0..100 {
            let x = g.sample(&mut rng);
            assert!(x % 100 == 0 && (100..=400).contains(&x));
        }
    }

    #[test]
    fn check_passes_valid_property() {
        check("sum-commutes", 42, 200, &vec_of(int_range(0, 100), int_range(0, 10)), |xs| {
            let fwd: u64 = xs.iter().sum();
            let rev: u64 = xs.iter().rev().sum();
            (fwd == rev).then_some(()).ok_or_else(|| "sum not commutative?!".into())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn check_reports_failures() {
        check("always-fails", 1, 10, &int_range(0, 10), |_| Err("nope".into()));
    }
}
