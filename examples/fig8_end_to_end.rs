//! End-to-end driver: exercises the FULL system on the paper's headline
//! experiment —
//!
//!   workload models (10 apps) → SIMT cores → four L1 organizations →
//!   cluster crossbars/rings → L2 crossbar → DRAM timing → metrics, PLUS
//!   the AOT JAX/Pallas locality artifact executed through PJRT to
//!   classify each workload.
//!
//! Prints Fig 8 (normalized IPC) and Fig 10 (L1 latency), the headline
//! averages, and writes results JSON.  Recorded in EXPERIMENTS.md §E2E.
//!
//!     cargo run --release --example fig8_end_to_end -- [--scale F] [--out FILE]

use ata_cache::config::L1ArchKind;
use ata_cache::coordinator::Sweep;
use ata_cache::runtime::LocalityAnalyzer;
use ata_cache::trace::signature::sample_core_traces;
use ata_cache::trace::{apps, LocalityClass};
use ata_cache::util::cli::Args;
use ata_cache::util::table::{pct_delta, BarChart, Table};
// lint: allow(wall-clock) — demo prints host elapsed time; nothing simulated reads it
use std::time::Instant;

fn main() {
    let args = Args::from_env().unwrap();
    let scale = args.get_f64("scale", 0.5).unwrap();
    let t0 = Instant::now(); // lint: allow(wall-clock) — host elapsed-time display only

    // ---- Stage 1: classify workloads through the PJRT artifact ---------
    println!("== stage 1: locality classification via AOT artifact (PJRT) ==");
    let analyzer = LocalityAnalyzer::load(args.get_or("artifacts", "artifacts"))
        .expect("run `make artifacts` first");
    let cfg = ata_cache::config::GpuConfig::paper(L1ArchKind::Private);
    let mut agree = 0;
    for app in apps::all_apps() {
        let wl = app.workload(&cfg);
        let traces = sample_core_traces(&wl, cfg.cores, analyzer.meta().trace_len);
        let report = analyzer.analyze(&traces).expect("artifact run");
        println!(
            "  {:10} score={:.3} replication={:.2}x -> {:?} (paper: {:?})",
            app.name,
            report.locality_score,
            report.replication_factor,
            report.class(),
            app.class
        );
        if report.class() == app.class {
            agree += 1;
        }
    }
    println!("  classification agreement: {agree}/10\n");

    // ---- Stage 2: the Fig 8 sweep over the full simulator ---------------
    println!("== stage 2: 4 architectures x 10 applications (scale {scale}) ==");
    let sweep = Sweep::paper(scale);
    let results = sweep.run();

    let mut fig8 = BarChart::new("Fig 8 — IPC normalized to private cache").baseline(1.0);
    let mut fig10 = Table::new("Fig 10 — L1 access latency (normalized to private)").header(&[
        "app", "remote", "decoupled", "ata",
    ]);
    for app in apps::all_app_names() {
        let ata = results.norm_ipc(L1ArchKind::Ata, app).unwrap();
        let dec = results.norm_ipc(L1ArchKind::DecoupledSharing, app).unwrap();
        fig8.bar(&format!("{app:9} decoupled"), dec);
        fig8.bar(&format!("{app:9} ata      "), ata);
        fig10.row(vec![
            app.to_string(),
            format!(
                "{:.2}x",
                results.norm_latency(L1ArchKind::RemoteSharing, app).unwrap()
            ),
            format!(
                "{:.2}x",
                results
                    .norm_latency(L1ArchKind::DecoupledSharing, app)
                    .unwrap()
            ),
            format!("{:.2}x", results.norm_latency(L1ArchKind::Ata, app).unwrap()),
        ]);
    }
    println!("{}", fig8.render());
    println!("{}", fig10.render());

    // ---- Stage 3: headline numbers --------------------------------------
    println!("== stage 3: headline metrics ==");
    let high_ata = results.class_geomean_ipc(L1ArchKind::Ata, LocalityClass::High);
    let low_ata = results.class_geomean_ipc(L1ArchKind::Ata, LocalityClass::Low);
    let low_dec = results.class_geomean_ipc(L1ArchKind::DecoupledSharing, LocalityClass::Low);
    println!(
        "  ATA IPC on high-locality apps: {} (paper: +12.0%)",
        pct_delta(high_ata)
    );
    println!(
        "  ATA vs decoupled on low-locality apps: {} (paper: +22.9%)",
        pct_delta(low_ata / low_dec)
    );
    let mut lat_dec = Vec::new();
    let mut lat_ata = Vec::new();
    for app in apps::all_app_names() {
        lat_dec.push(results.norm_latency(L1ArchKind::DecoupledSharing, app).unwrap());
        lat_ata.push(results.norm_latency(L1ArchKind::Ata, app).unwrap());
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let max = |v: &[f64]| v.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "  decoupled L1 latency: +{:.1}% avg, up to {:.2}x (paper: +67.2%, up to 2.74x)",
        (mean(&lat_dec) - 1.0) * 100.0,
        max(&lat_dec)
    );
    println!(
        "  ATA L1 latency: +{:.1}% avg (paper: +6.0%)",
        (mean(&lat_ata) - 1.0) * 100.0
    );

    let total_cycles: u64 = results.results.iter().map(|r| r.cycles).sum();
    println!(
        "\nend-to-end complete: {} sims, {:.1}M simulated cycles, {:.1}s wall clock",
        results.results.len(),
        total_cycles as f64 / 1e6,
        t0.elapsed().as_secs_f64()
    );

    let out = args.get_or("out", "fig8_results.json");
    results.save(out).expect("write results");
    println!("results written to {out}");
}
