//! Locality analysis through the AOT Pallas/JAX artifact.
//!
//! Shows the Rust↔PJRT integration in isolation: per-core traces from the
//! workload models flow through the aggregated-signature matmul kernel
//! compiled from `python/compile/`, and the resulting sharing matrix /
//! locality score / replication factor are compared against exact set
//! arithmetic computed in Rust.
//!
//!     cargo run --release --example locality_analysis

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::runtime::LocalityAnalyzer;
use ata_cache::trace::signature::{exact_locality, sample_core_traces};
use ata_cache::trace::apps;
use ata_cache::util::table::Table;

fn main() {
    let analyzer = LocalityAnalyzer::load("artifacts").expect("run `make artifacts` first");
    let meta = analyzer.meta();
    println!(
        "artifact: {} cores (padded {}), {} samples/core, {} hash buckets\n",
        meta.num_cores, meta.padded_cores, meta.trace_len, meta.nbits
    );

    let cfg = GpuConfig::paper(L1ArchKind::Private);
    let mut t = Table::new("PJRT artifact vs exact set arithmetic").header(&[
        "app", "score (artifact)", "score (exact)", "err", "repl (artifact)", "repl (exact)", "class",
    ]);
    let mut worst_err: f64 = 0.0;
    for app in apps::all_apps() {
        let wl = app.workload(&cfg);
        let traces = sample_core_traces(&wl, cfg.cores, meta.trace_len);
        let report = analyzer.analyze(&traces).expect("artifact execution");
        let (score, repl) = exact_locality(&traces);
        let err = (report.locality_score as f64 - score).abs();
        worst_err = worst_err.max(err);
        t.row(vec![
            app.name.to_string(),
            format!("{:.4}", report.locality_score),
            format!("{score:.4}"),
            format!("{err:.4}"),
            format!("{:.2}", report.replication_factor),
            format!("{repl:.2}"),
            format!("{:?}", report.class()),
        ]);
    }
    println!("{}", t.render());
    println!("worst |artifact - exact| score error: {worst_err:.4} (hash-bucket estimate)");

    // Peek at the sharing matrix for one high-locality app.
    let app = apps::app("SN").unwrap();
    let traces = sample_core_traces(&app.workload(&cfg), cfg.cores, meta.trace_len);
    let report = analyzer.analyze(&traces).unwrap();
    println!("\nSN sharing matrix (cores 0..6, bucket-intersection counts):");
    for i in 0..6 {
        let row: Vec<String> = (0..6)
            .map(|j| format!("{:6.0}", report.shared_with(i, j)))
            .collect();
        println!("  core{i}: [{}]", row.join(" "));
    }
    assert!(worst_err < 0.05, "hash estimate must track exact sets");
}
