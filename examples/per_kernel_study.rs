//! Per-kernel performance study (Fig 9): kernels of SN, conv3d, HS3D and
//! sradv1 under decoupled-sharing and ATA-Cache, normalized to private.
//!
//! The paper's observations this regenerates:
//!   * SN: decoupled degrades several kernels; ATA's overall win is larger.
//!   * conv3d, HS3D: ATA beats decoupled on every kernel.
//!   * sradv1: kernels 4, 9, 14 crater under decoupled (reduction-style
//!     convergence on few home slices).
//!
//!     cargo run --release --example per_kernel_study -- [--scale F]

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::engine::Engine;
use ata_cache::stats::SimResult;
use ata_cache::trace::apps;
use ata_cache::util::cli::Args;
use ata_cache::util::table::Table;

fn run(app: &str, arch: L1ArchKind, scale: f64) -> SimResult {
    let cfg = GpuConfig::paper(arch);
    let wl = apps::app(app).unwrap().scaled(scale).workload(&cfg);
    Engine::new(&cfg).run(&wl).unwrap()
}

fn main() {
    let args = Args::from_env().unwrap();
    let scale = args.get_f64("scale", 0.5).unwrap();

    for app in ["SN", "conv3d", "HS3D", "sradv1"] {
        let base = run(app, L1ArchKind::Private, scale);
        let dec = run(app, L1ArchKind::DecoupledSharing, scale);
        let ata = run(app, L1ArchKind::Ata, scale);

        let mut t = Table::new(&format!("Fig 9 — {app}: per-kernel IPC normalized to private"))
            .header(&["kernel", "decoupled", "ata", "ata beats dec?"]);
        let mut dec_wins = 0;
        for (i, k) in base.kernels.iter().enumerate() {
            let b = k.ipc().max(1e-12);
            let d = dec.kernels[i].ipc() / b;
            let a = ata.kernels[i].ipc() / b;
            if a >= d {
                dec_wins += 1;
            }
            t.row(vec![
                format!("k{i}:{}", k.name),
                format!("{d:.3}"),
                format!("{a:.3}"),
                if a >= d { "yes".into() } else { "no".into() },
            ]);
        }
        println!("{}", t.render());
        println!(
            "  ATA >= decoupled on {dec_wins}/{} kernels; whole-app: dec {:.3} ata {:.3}\n",
            base.kernels.len(),
            dec.ipc() / base.ipc(),
            ata.ipc() / base.ipc()
        );
    }
}
