//! Quickstart: simulate one application on the private baseline and on
//! ATA-Cache, and print the paper's headline comparison.
//!
//!     cargo run --release --example quickstart

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::engine::Engine;
use ata_cache::trace::apps;
use ata_cache::util::table::pct_delta;

fn main() {
    // 1. Pick a workload model — SqueezeNet (Tango), a high inter-core
    //    locality app: every core streams the same filter weights.
    let app = apps::app("SN").expect("SN is a built-in model");
    println!("app: {} ({}, {:?} locality)", app.name, app.suite, app.class);
    println!("     {}", app.notes);

    // 2. Simulate under the conventional private L1 (Table II GPU).
    let cfg_private = GpuConfig::paper(L1ArchKind::Private);
    let wl = app.scaled(0.5).workload(&cfg_private);
    let base = Engine::new(&cfg_private).run(&wl).unwrap();

    // 3. Same workload on ATA-Cache.
    let cfg_ata = GpuConfig::paper(L1ArchKind::Ata);
    let ata = Engine::new(&cfg_ata).run(&wl).unwrap();

    // 4. Compare.
    println!("\n{:<26} {:>12} {:>12}", "", "private", "ata-cache");
    println!("{:<26} {:>12.4} {:>12.4}", "IPC", base.ipc(), ata.ipc());
    println!(
        "{:<26} {:>11.1}% {:>11.1}%",
        "L1 hit rate",
        base.l1.hit_rate() * 100.0,
        ata.l1.hit_rate() * 100.0
    );
    println!(
        "{:<26} {:>12} {:>12}",
        "remote hits", base.l1.remote_hits, ata.l1.remote_hits
    );
    println!(
        "{:<26} {:>12.1} {:>12.1}",
        "L1 access latency (cyc)", base.l1_stage_mean_latency, ata.l1_stage_mean_latency
    );
    println!(
        "{:<26} {:>11.1}% {:>11.1}%",
        "L2 hit rate",
        base.l2_hit_rate * 100.0,
        ata.l2_hit_rate * 100.0
    );
    println!(
        "\nATA-Cache IPC vs private: {}",
        pct_delta(ata.ipc() / base.ipc())
    );
    assert!(ata.ipc() >= base.ipc() * 0.99, "ATA should not lose");
}
