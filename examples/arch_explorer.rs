//! Architecture explorer: sweep the synthetic inter-core-locality knob
//! from 0 to 1 and watch the four organizations cross over — the design-
//! space view behind Table I.
//!
//! Also runs the paper's two corner cases:
//!   * pure streaming (zero sharing): ATA must match private ("no
//!     performance impairment due to sharing"),
//!   * convergent hammer: decoupled's worst case.
//!
//!     cargo run --release --example arch_explorer -- [--quick]

use ata_cache::config::{GpuConfig, L1ArchKind};
use ata_cache::coordinator::Sweep;
use ata_cache::trace::synth;
use ata_cache::util::cli::Args;
use ata_cache::util::table::Table;

fn main() {
    let args = Args::from_env().unwrap();
    let intensity = if args.flag("quick") { 0.25 } else { 0.5 };

    // ---- locality-knob sweep --------------------------------------------
    let knobs = [0.0, 0.2, 0.4, 0.6, 0.8, 0.95];
    let sweep = Sweep {
        cfg: GpuConfig::paper(L1ArchKind::Private),
        archs: L1ArchKind::ALL.to_vec(),
        apps: knobs.iter().map(|&s| synth::locality_knob(s, intensity)).collect(),
        scale: 1.0,
        // lint: allow(shard-confinement) — CLI example sizing its worker pool; no simulation state crosses threads
        threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };
    let results = sweep.run();

    let mut t = Table::new("normalized IPC vs inter-core locality knob").header(&[
        "sharing", "remote", "decoupled", "ata",
    ]);
    for (i, &s) in knobs.iter().enumerate() {
        let app = sweep.apps[i].name;
        t.row(vec![
            format!("{s:.2}"),
            format!("{:.3}", results.norm_ipc(L1ArchKind::RemoteSharing, app).unwrap()),
            format!("{:.3}", results.norm_ipc(L1ArchKind::DecoupledSharing, app).unwrap()),
            format!("{:.3}", results.norm_ipc(L1ArchKind::Ata, app).unwrap()),
        ]);
    }
    println!("{}", t.render());

    // ATA's gain must grow with the knob.
    let lo = results.norm_ipc(L1ArchKind::Ata, sweep.apps[0].name).unwrap();
    let hi = results.norm_ipc(L1ArchKind::Ata, sweep.apps[5].name).unwrap();
    println!("ATA gain at knob 0.0: {lo:.3}; at 0.95: {hi:.3}");

    // ---- corner cases ----------------------------------------------------
    let corner = Sweep {
        cfg: GpuConfig::paper(L1ArchKind::Private),
        archs: vec![L1ArchKind::Private, L1ArchKind::DecoupledSharing, L1ArchKind::Ata],
        apps: vec![synth::pure_streaming(), synth::convergent_hammer()],
        scale: intensity,
        threads: 4,
    };
    let cr = corner.run();
    let mut t2 = Table::new("corner cases").header(&["workload", "decoupled", "ata"]);
    for app in ["synth[stream]", "synth[hammer]"] {
        t2.row(vec![
            app.to_string(),
            format!("{:.3}", cr.norm_ipc(L1ArchKind::DecoupledSharing, app).unwrap()),
            format!("{:.3}", cr.norm_ipc(L1ArchKind::Ata, app).unwrap()),
        ]);
    }
    println!("{}", t2.render());
    let stream_ata = cr.norm_ipc(L1ArchKind::Ata, "synth[stream]").unwrap();
    println!(
        "zero-sharing ATA vs private: {stream_ata:.4} (paper claim: no impairment)"
    );
}
